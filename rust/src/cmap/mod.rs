//! Concurrent map substrates for the key-value store evaluation (§6.3):
//!
//! - [`ShardedMutexMap`] — the paper's "naïvely sharded HashMap" with
//!   `std::sync::Mutex` per shard (512 shards by default, "many more locks
//!   than threads").
//! - [`ShardedRwMap`] — same, with readers-writer locks.
//! - [`SwiftMap`] — the Dashmap stand-in: sharded `RwLock` over our
//!   open-addressing robin-hood [`OaTable`] (Dashmap's own design), with a
//!   lower-overhead fixed-shard layout and FxHash.
//!
//! All three expose the same minimal interface the KV store needs
//! (`get` → owned value, `insert`, `remove`, `len`), so the bench harness
//! is generic via [`ConcurrentMap`].

pub mod oatable;

pub use oatable::{fxhash, FxHasher, OaTable};

use std::borrow::Borrow;
use std::collections::HashMap;
use std::hash::Hash;
use std::sync::{Mutex, RwLock};

/// The operations the KV store and benches need, generic over the
/// backend. Lookup entry points are **borrow-keyed** (`Q: Borrow`-style,
/// like `HashMap`): callers holding a `&[u8]` key probe a
/// `Vec<u8>`-keyed map without allocating an owned key first — the
/// lock-baseline half of the one-copy GET contract (DESIGN.md,
/// "Allocation discipline").
pub trait ConcurrentMap<K, V>: Send + Sync {
    /// Owned-copy lookup.
    fn get<Q>(&self, k: &Q) -> Option<V>
    where
        K: Borrow<Q>,
        Q: Eq + Hash + ?Sized;
    /// Borrow-based lookup: run `f` on the value **in place** (under the
    /// shard's read lock) without copying it out. `f` must not touch the
    /// map. This is how `AsyncKv::get` renders a value straight into the
    /// wire buffer with exactly one copy.
    fn with_get<Q, R, F>(&self, k: &Q, f: F) -> R
    where
        K: Borrow<Q>,
        Q: Eq + Hash + ?Sized,
        F: FnOnce(Option<&V>) -> R;
    fn insert(&self, k: K, v: V) -> Option<V>;
    fn remove<Q>(&self, k: &Q) -> Option<V>
    where
        K: Borrow<Q>,
        Q: Eq + Hash + ?Sized;
    /// Presence check without cloning the value out — and, on the
    /// RwLock-based maps, without taking the write lock (RESP `EXISTS`
    /// is read-only and must scale like one).
    fn contains<Q>(&self, k: &Q) -> bool
    where
        K: Borrow<Q>,
        Q: Eq + Hash + ?Sized;
    fn len(&self) -> usize;
    fn is_empty(&self) -> bool {
        self.len() == 0
    }
    /// Read-modify-write (used by fetch-and-add style workloads).
    fn update<Q, R>(&self, k: &Q, f: &mut dyn FnMut(Option<&mut V>) -> R) -> R
    where
        K: Borrow<Q>,
        Q: Eq + Hash + ?Sized;
    /// Read-modify-write that can also **insert or remove**: `f` receives
    /// the entry slot (`None` when absent) under the shard's write lock;
    /// leaving `Some` (re)inserts, leaving `None` removes. Used by the
    /// RESP front end's atomic `INCR`.
    fn entry_update<R>(&self, k: K, f: &mut dyn FnMut(&mut Option<V>) -> R) -> R;
    /// Remove every entry (RESP `FLUSHALL`).
    fn clear(&self);
}

#[inline]
fn shard_of<K: Hash + ?Sized>(k: &K, n_shards: usize) -> usize {
    (fxhash(k) as usize >> 7) & (n_shards - 1)
}

macro_rules! sharded_map {
    ($name:ident, $lock:ident, $read:ident, $write:ident, $doc:literal) => {
        #[doc = $doc]
        pub struct $name<K, V> {
            shards: Vec<$lock<HashMap<K, V>>>,
        }

        impl<K: Eq + Hash, V> $name<K, V> {
            /// `n_shards` is rounded up to a power of two (default 512,
            /// the paper's §6.3 configuration).
            pub fn new(n_shards: usize) -> Self {
                let n = n_shards.next_power_of_two().max(1);
                let mut shards = Vec::with_capacity(n);
                shards.resize_with(n, || $lock::new(HashMap::new()));
                Self { shards }
            }

            pub fn n_shards(&self) -> usize {
                self.shards.len()
            }
        }

        impl<K: Eq + Hash, V> Default for $name<K, V> {
            fn default() -> Self {
                Self::new(512)
            }
        }

        impl<K, V> ConcurrentMap<K, V> for $name<K, V>
        where
            K: Eq + Hash + Send + Sync,
            V: Clone + Send + Sync,
        {
            fn get<Q>(&self, k: &Q) -> Option<V>
            where
                K: Borrow<Q>,
                Q: Eq + Hash + ?Sized,
            {
                let shard = &self.shards[shard_of(k, self.shards.len())];
                shard.$read().unwrap().get(k).cloned()
            }

            fn with_get<Q, R, F>(&self, k: &Q, f: F) -> R
            where
                K: Borrow<Q>,
                Q: Eq + Hash + ?Sized,
                F: FnOnce(Option<&V>) -> R,
            {
                let shard = &self.shards[shard_of(k, self.shards.len())];
                let g = shard.$read().unwrap();
                f(g.get(k))
            }

            fn insert(&self, k: K, v: V) -> Option<V> {
                let shard = &self.shards[shard_of(&k, self.shards.len())];
                shard.$write().unwrap().insert(k, v)
            }

            fn remove<Q>(&self, k: &Q) -> Option<V>
            where
                K: Borrow<Q>,
                Q: Eq + Hash + ?Sized,
            {
                let shard = &self.shards[shard_of(k, self.shards.len())];
                shard.$write().unwrap().remove(k)
            }

            fn contains<Q>(&self, k: &Q) -> bool
            where
                K: Borrow<Q>,
                Q: Eq + Hash + ?Sized,
            {
                let shard = &self.shards[shard_of(k, self.shards.len())];
                shard.$read().unwrap().contains_key(k)
            }

            fn len(&self) -> usize {
                self.shards.iter().map(|s| s.$read().unwrap().len()).sum()
            }

            fn update<Q, R>(&self, k: &Q, f: &mut dyn FnMut(Option<&mut V>) -> R) -> R
            where
                K: Borrow<Q>,
                Q: Eq + Hash + ?Sized,
            {
                let shard = &self.shards[shard_of(k, self.shards.len())];
                f(shard.$write().unwrap().get_mut(k))
            }

            fn entry_update<R>(&self, k: K, f: &mut dyn FnMut(&mut Option<V>) -> R) -> R {
                let shard = &self.shards[shard_of(&k, self.shards.len())];
                let mut g = shard.$write().unwrap();
                let mut slot = g.remove(&k);
                let r = f(&mut slot);
                if let Some(v) = slot {
                    g.insert(k, v);
                }
                r
            }

            fn clear(&self) {
                for s in &self.shards {
                    s.$write().unwrap().clear();
                }
            }
        }
    };
}

sharded_map!(
    ShardedMutexMap,
    Mutex,
    lock,
    lock,
    "Sharded `HashMap` with one `Mutex` per shard (paper §6.3 \"Mutex\")."
);
sharded_map!(
    ShardedRwMap,
    RwLock,
    read,
    write,
    "Sharded `HashMap` with one `RwLock` per shard (paper §6.3 \"RwLock\")."
);

/// Dashmap stand-in: fixed power-of-two shards, each an
/// `RwLock<OaTable<K, V>>` — structurally what Dashmap 5.x does, built on
/// our own robin-hood table.
pub struct SwiftMap<K, V> {
    shards: Vec<RwLock<OaTable<K, V>>>,
}

impl<K: Eq + Hash, V> SwiftMap<K, V> {
    pub fn new(n_shards: usize) -> Self {
        let n = n_shards.next_power_of_two().max(1);
        let mut shards = Vec::with_capacity(n);
        shards.resize_with(n, || RwLock::new(OaTable::default()));
        SwiftMap { shards }
    }

    pub fn with_capacity(n_shards: usize, cap: usize) -> Self {
        let n = n_shards.next_power_of_two().max(1);
        let per = (cap / n).max(8);
        let mut shards = Vec::with_capacity(n);
        shards.resize_with(n, || RwLock::new(OaTable::with_capacity(per)));
        SwiftMap { shards }
    }

    pub fn n_shards(&self) -> usize {
        self.shards.len()
    }
}

impl<K: Eq + Hash, V> Default for SwiftMap<K, V> {
    fn default() -> Self {
        SwiftMap::new(64) // dashmap defaults to 4*ncpu, rounded up
    }
}

impl<K, V> ConcurrentMap<K, V> for SwiftMap<K, V>
where
    K: Eq + Hash + Send + Sync,
    V: Clone + Send + Sync,
{
    fn get<Q>(&self, k: &Q) -> Option<V>
    where
        K: Borrow<Q>,
        Q: Eq + Hash + ?Sized,
    {
        let shard = &self.shards[shard_of(k, self.shards.len())];
        shard.read().unwrap().get(k).cloned()
    }

    fn with_get<Q, R, F>(&self, k: &Q, f: F) -> R
    where
        K: Borrow<Q>,
        Q: Eq + Hash + ?Sized,
        F: FnOnce(Option<&V>) -> R,
    {
        let shard = &self.shards[shard_of(k, self.shards.len())];
        let g = shard.read().unwrap();
        f(g.get(k))
    }

    fn insert(&self, k: K, v: V) -> Option<V> {
        let shard = &self.shards[shard_of(&k, self.shards.len())];
        shard.write().unwrap().insert(k, v)
    }

    fn remove<Q>(&self, k: &Q) -> Option<V>
    where
        K: Borrow<Q>,
        Q: Eq + Hash + ?Sized,
    {
        let shard = &self.shards[shard_of(k, self.shards.len())];
        shard.write().unwrap().remove(k)
    }

    fn contains<Q>(&self, k: &Q) -> bool
    where
        K: Borrow<Q>,
        Q: Eq + Hash + ?Sized,
    {
        let shard = &self.shards[shard_of(k, self.shards.len())];
        shard.read().unwrap().contains_key(k)
    }

    fn len(&self) -> usize {
        self.shards.iter().map(|s| s.read().unwrap().len()).sum()
    }

    fn update<Q, R>(&self, k: &Q, f: &mut dyn FnMut(Option<&mut V>) -> R) -> R
    where
        K: Borrow<Q>,
        Q: Eq + Hash + ?Sized,
    {
        let shard = &self.shards[shard_of(k, self.shards.len())];
        f(shard.write().unwrap().get_mut(k))
    }

    fn entry_update<R>(&self, k: K, f: &mut dyn FnMut(&mut Option<V>) -> R) -> R {
        let shard = &self.shards[shard_of(&k, self.shards.len())];
        let mut g = shard.write().unwrap();
        let mut slot = g.remove(&k);
        let r = f(&mut slot);
        if let Some(v) = slot {
            g.insert(k, v);
        }
        r
    }

    fn clear(&self) {
        for s in &self.shards {
            s.write().unwrap().clear();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    fn exercise<M: ConcurrentMap<u64, u64> + 'static>(map: Arc<M>) {
        let threads = 4;
        let per = 1000u64;
        let handles: Vec<_> = (0..threads)
            .map(|t| {
                let map = map.clone();
                std::thread::spawn(move || {
                    let base = t as u64 * per;
                    for i in 0..per {
                        map.insert(base + i, i);
                    }
                    for i in 0..per {
                        assert_eq!(map.get(&(base + i)), Some(i));
                    }
                    for i in (0..per).step_by(2) {
                        assert_eq!(map.remove(&(base + i)), Some(i));
                    }
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(map.len(), threads as usize * (per as usize) / 2);
    }

    #[test]
    fn sharded_mutex_map_concurrent() {
        exercise(Arc::new(ShardedMutexMap::new(64)));
    }

    #[test]
    fn sharded_rw_map_concurrent() {
        exercise(Arc::new(ShardedRwMap::new(64)));
    }

    #[test]
    fn swift_map_concurrent() {
        exercise(Arc::new(SwiftMap::new(64)));
    }

    #[test]
    fn update_read_modify_write() {
        let m = SwiftMap::new(4);
        m.insert(1u64, 10u64);
        let old = m.update(&1, &mut |v| {
            let v = v.unwrap();
            let o = *v;
            *v += 1;
            o
        });
        assert_eq!(old, 10);
        assert_eq!(m.get(&1), Some(11));
        let missing = m.update(&99, &mut |v| v.is_none());
        assert!(missing);
    }

    #[test]
    fn entry_update_inserts_and_removes() {
        fn exercise<M: ConcurrentMap<u64, u64>>(m: &M) {
            // Insert through the slot.
            let r = m.entry_update(1, &mut |slot| {
                assert!(slot.is_none());
                *slot = Some(10);
                "inserted"
            });
            assert_eq!(r, "inserted");
            assert_eq!(m.get(&1), Some(10));
            // In-place RMW through the slot.
            m.entry_update(1, &mut |slot| {
                *slot.as_mut().unwrap() += 5;
            });
            assert_eq!(m.get(&1), Some(15));
            // Remove by leaving None.
            m.entry_update(1, &mut |slot| {
                assert_eq!(slot.take(), Some(15));
            });
            assert_eq!(m.get(&1), None);
            assert_eq!(m.len(), 0);
        }
        exercise(&ShardedMutexMap::new(8));
        exercise(&ShardedRwMap::new(8));
        exercise(&SwiftMap::new(8));
    }

    #[test]
    fn contains_tracks_membership() {
        fn exercise<M: ConcurrentMap<u64, u64>>(m: &M) {
            assert!(!m.contains(&1));
            m.insert(1, 10);
            assert!(m.contains(&1));
            m.remove(&1);
            assert!(!m.contains(&1));
        }
        exercise(&ShardedMutexMap::new(8));
        exercise(&ShardedRwMap::new(8));
        exercise(&SwiftMap::new(8));
    }

    #[test]
    fn clear_empties_every_shard() {
        fn exercise<M: ConcurrentMap<u64, u64>>(m: &M) {
            for i in 0..100 {
                m.insert(i, i);
            }
            assert_eq!(m.len(), 100);
            m.clear();
            assert_eq!(m.len(), 0);
            assert_eq!(m.get(&7), None);
            // Still usable after clear.
            m.insert(7, 7);
            assert_eq!(m.get(&7), Some(7));
        }
        exercise(&ShardedMutexMap::new(8));
        exercise(&ShardedRwMap::new(8));
        exercise(&SwiftMap::new(8));
    }

    #[test]
    fn borrowed_key_lookups_and_with_get() {
        // Byte-keyed maps must answer &[u8] probes without an owned key,
        // and with_get must expose the value in place (one-copy GET).
        fn exercise<M: ConcurrentMap<Vec<u8>, Vec<u8>>>(m: &M) {
            m.insert(b"alpha".to_vec(), b"one".to_vec());
            assert_eq!(m.get::<[u8]>(b"alpha"), Some(b"one".to_vec()));
            assert!(m.contains::<[u8]>(b"alpha"));
            assert!(!m.contains::<[u8]>(b"beta"));
            let len = m.with_get::<[u8], _, _>(b"alpha", |v| v.map_or(0, |v| v.len()));
            assert_eq!(len, 3);
            let miss = m.with_get::<[u8], _, _>(b"beta", |v| v.is_none());
            assert!(miss);
            let bumped = m.update::<[u8], _>(b"alpha", &mut |v| {
                if let Some(v) = v {
                    v.push(b'!');
                    true
                } else {
                    false
                }
            });
            assert!(bumped);
            assert_eq!(m.remove::<[u8]>(b"alpha"), Some(b"one!".to_vec()));
            assert_eq!(m.len(), 0);
        }
        exercise(&ShardedMutexMap::new(8));
        exercise(&ShardedRwMap::new(8));
        exercise(&SwiftMap::new(8));
    }

    #[test]
    fn shard_counts_power_of_two() {
        assert_eq!(ShardedMutexMap::<u64, u64>::new(500).n_shards(), 512);
        assert_eq!(SwiftMap::<u64, u64>::new(3).n_shards(), 4);
    }

    #[test]
    fn string_keys_work() {
        let m = SwiftMap::default();
        m.insert("alpha".to_string(), 1u32);
        m.insert("beta".to_string(), 2);
        assert_eq!(m.get(&"alpha".to_string()), Some(1));
        assert_eq!(m.remove(&"beta".to_string()), Some(2));
        assert_eq!(m.len(), 1);
    }
}
