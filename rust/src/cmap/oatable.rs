//! Open-addressing robin-hood hash table — the from-scratch table behind
//! every shard of the unified item store
//! ([`ItemShard`](crate::kvstore::store::ItemShard)), delegated and
//! lock-wrapped alike.
//!
//! Robin-hood insertion with backward-shift deletion (no tombstones) keeps
//! probe sequences short under churn, which matters for the write-heavy
//! sweeps in Fig. 9. Hashing is FxHash (the rustc hash): two multiplies per
//! word, deterministic across runs (bench reproducibility).

/// FxHash, as used by rustc. Deterministic; not DoS-resistant (fine for
/// benches and trusted keys).
#[derive(Default, Clone)]
pub struct FxHasher {
    hash: u64,
}

const SEED: u64 = 0x51_7c_c1_b7_27_22_0a_95;

impl std::hash::Hasher for FxHasher {
    #[inline]
    fn finish(&self) -> u64 {
        self.hash
    }

    #[inline]
    fn write(&mut self, bytes: &[u8]) {
        for &b in bytes {
            self.hash = (self.hash.rotate_left(5) ^ b as u64).wrapping_mul(SEED);
        }
    }

    #[inline]
    fn write_u64(&mut self, n: u64) {
        self.hash = (self.hash.rotate_left(5) ^ n).wrapping_mul(SEED);
    }

    #[inline]
    fn write_usize(&mut self, n: usize) {
        self.write_u64(n as u64);
    }
}

/// Hash a key with FxHash.
#[inline]
pub fn fxhash<K: std::hash::Hash + ?Sized>(k: &K) -> u64 {
    use std::hash::Hasher;
    let mut h = FxHasher::default();
    k.hash(&mut h);
    // Final avalanche so low bits are usable as bucket indices.
    crate::util::rng::mix64(h.finish())
}

struct Entry<K, V> {
    hash: u64,
    key: K,
    value: V,
}

/// Open-addressing robin-hood table.
pub struct OaTable<K, V> {
    slots: Vec<Option<Entry<K, V>>>,
    mask: usize,
    len: usize,
}

impl<K: Eq + std::hash::Hash, V> Default for OaTable<K, V> {
    fn default() -> Self {
        Self::with_capacity(8)
    }
}

impl<K: Eq + std::hash::Hash, V> OaTable<K, V> {
    pub fn with_capacity(cap: usize) -> Self {
        let cap = cap.next_power_of_two().max(8);
        let mut slots = Vec::with_capacity(cap);
        slots.resize_with(cap, || None);
        OaTable { slots, mask: cap - 1, len: 0 }
    }

    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    pub fn capacity(&self) -> usize {
        self.slots.len()
    }

    /// Remove every entry, keeping the allocated slot array.
    pub fn clear(&mut self) {
        for s in self.slots.iter_mut() {
            *s = None;
        }
        self.len = 0;
    }

    #[inline]
    fn distance(&self, hash: u64, slot: usize) -> usize {
        let home = (hash as usize) & self.mask;
        slot.wrapping_sub(home) & self.mask
    }

    fn grow(&mut self) {
        let mut bigger = OaTable::with_capacity(self.slots.len() * 2);
        for e in self.slots.drain(..).flatten() {
            bigger.insert_hashed(e.hash, e.key, e.value);
        }
        *self = bigger;
    }

    /// Insert with a precomputed [`fxhash`] of `key`. Callers that
    /// already hold the hash (the item store keeps it on each entry for
    /// [`OaTable::find_slot_by_hash`]) avoid hashing the key twice;
    /// passing anything other than `fxhash(&key)` corrupts the probe
    /// sequence.
    pub fn insert_hashed(&mut self, hash: u64, key: K, value: V) -> Option<V> {
        if (self.len + 1) * 4 > self.slots.len() * 3 {
            self.grow();
        }
        let mask = self.mask;
        let distance = |hash: u64, slot: usize| slot.wrapping_sub((hash as usize) & mask) & mask;
        let mut idx = (hash as usize) & mask;
        let mut probe = Entry { hash, key, value };
        let mut dist = 0usize;
        loop {
            match &mut self.slots[idx] {
                slot @ None => {
                    *slot = Some(probe);
                    self.len += 1;
                    return None;
                }
                Some(e) if e.hash == probe.hash && e.key == probe.key => {
                    return Some(std::mem::replace(&mut e.value, probe.value));
                }
                Some(e) => {
                    let their_dist = distance(e.hash, idx);
                    if their_dist < dist {
                        // Robin hood: steal from the rich.
                        std::mem::swap(e, &mut probe);
                        dist = their_dist;
                    }
                }
            }
            idx = (idx + 1) & mask;
            dist += 1;
        }
    }

    pub fn insert(&mut self, key: K, value: V) -> Option<V> {
        let hash = fxhash(&key);
        self.insert_hashed(hash, key, value)
    }

    #[inline]
    fn find_slot<Q>(&self, key: &Q) -> Option<usize>
    where
        K: std::borrow::Borrow<Q>,
        Q: Eq + std::hash::Hash + ?Sized,
    {
        let hash = fxhash(key);
        let mut idx = (hash as usize) & self.mask;
        let mut dist = 0usize;
        loop {
            match &self.slots[idx] {
                None => return None,
                Some(e) => {
                    if e.hash == hash && e.key.borrow() == key {
                        return Some(idx);
                    }
                    // Robin-hood invariant: if this entry is closer to home
                    // than our probe distance, the key cannot be present.
                    if self.distance(e.hash, idx) < dist {
                        return None;
                    }
                }
            }
            idx = (idx + 1) & self.mask;
            dist += 1;
            if dist > self.slots.len() {
                return None;
            }
        }
    }

    pub fn get<Q>(&self, key: &Q) -> Option<&V>
    where
        K: std::borrow::Borrow<Q>,
        Q: Eq + std::hash::Hash + ?Sized,
    {
        self.find_slot(key).map(|i| &self.slots[i].as_ref().unwrap().value)
    }

    pub fn get_mut<Q>(&mut self, key: &Q) -> Option<&mut V>
    where
        K: std::borrow::Borrow<Q>,
        Q: Eq + std::hash::Hash + ?Sized,
    {
        self.find_slot(key)
            .map(|i| &mut self.slots[i].as_mut().unwrap().value)
    }

    pub fn contains_key<Q>(&self, key: &Q) -> bool
    where
        K: std::borrow::Borrow<Q>,
        Q: Eq + std::hash::Hash + ?Sized,
    {
        self.find_slot(key).is_some()
    }

    pub fn remove<Q>(&mut self, key: &Q) -> Option<V>
    where
        K: std::borrow::Borrow<Q>,
        Q: Eq + std::hash::Hash + ?Sized,
    {
        let idx = self.find_slot(key)?;
        self.remove_at(idx).map(|(_, v)| v)
    }

    /// Slot index holding `key`, for the slot-addressed entry points
    /// below (LRU victim scans and the incremental expiry sweep address
    /// entries by slot so they never build an owned key).
    pub fn index_of<Q>(&self, key: &Q) -> Option<usize>
    where
        K: std::borrow::Borrow<Q>,
        Q: Eq + std::hash::Hash + ?Sized,
    {
        self.find_slot(key)
    }

    /// Find the slot whose entry has hash `hash` and whose *value*
    /// satisfies `pred`, probing from the hash's home slot with the same
    /// robin-hood early exit as a keyed lookup — expected O(1), worst
    /// case the probe-sequence length, never a table scan.
    ///
    /// This is the reverse lookup behind O(1) eviction: an entry that
    /// knows its own hash (and is identified by its value, e.g. a slab
    /// handle) can locate its table slot without an owned key and
    /// without scanning `capacity()` slots.
    pub fn find_slot_by_hash(&self, hash: u64, mut pred: impl FnMut(&V) -> bool) -> Option<usize> {
        let mut idx = (hash as usize) & self.mask;
        let mut dist = 0usize;
        loop {
            match &self.slots[idx] {
                None => return None,
                Some(e) => {
                    if e.hash == hash && pred(&e.value) {
                        return Some(idx);
                    }
                    // Robin-hood invariant: entries closer to home than
                    // our probe distance rule out a match further on.
                    if self.distance(e.hash, idx) < dist {
                        return None;
                    }
                }
            }
            idx = (idx + 1) & self.mask;
            dist += 1;
            if dist > self.slots.len() {
                return None;
            }
        }
    }

    /// The entry in slot `idx` (`None` for an empty slot). Slot indices
    /// are only stable until the next insert/remove — they are scan
    /// cursors, not handles.
    pub fn entry_at(&self, idx: usize) -> Option<(&K, &V)> {
        self.slots
            .get(idx)
            .and_then(|s| s.as_ref().map(|e| (&e.key, &e.value)))
    }

    /// Mutable view of the entry in slot `idx` (the key stays shared —
    /// mutating it would corrupt the probe sequence).
    pub fn entry_at_mut(&mut self, idx: usize) -> Option<(&K, &mut V)> {
        self.slots
            .get_mut(idx)
            .and_then(|s| s.as_mut().map(|e| (&e.key, &mut e.value)))
    }

    /// Remove the entry in slot `idx`, returning it. Backward-shift
    /// deletion runs from `idx`, so after removal the *same* slot may
    /// hold a shifted-in successor — sweep loops must re-examine `idx`
    /// before advancing.
    pub fn remove_at(&mut self, mut idx: usize) -> Option<(K, V)> {
        let removed = self.slots.get_mut(idx)?.take()?;
        self.len -= 1;
        // Backward-shift deletion: pull successors left until a hole or a
        // home-positioned entry.
        loop {
            let next = (idx + 1) & self.mask;
            let shift = match &self.slots[next] {
                Some(e) => self.distance(e.hash, next) > 0,
                None => false,
            };
            if !shift {
                break;
            }
            self.slots[idx] = self.slots[next].take();
            idx = next;
        }
        Some((removed.key, removed.value))
    }

    pub fn iter(&self) -> impl Iterator<Item = (&K, &V)> {
        self.slots
            .iter()
            .filter_map(|s| s.as_ref().map(|e| (&e.key, &e.value)))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::quickcheck::check;
    use std::collections::HashMap;

    #[test]
    fn insert_get_remove_basic() {
        let mut t = OaTable::default();
        assert_eq!(t.insert("a".to_string(), 1), None);
        assert_eq!(t.insert("b".to_string(), 2), None);
        assert_eq!(t.insert("a".to_string(), 3), Some(1));
        assert_eq!(t.get("a"), Some(&3));
        assert_eq!(t.get("b"), Some(&2));
        assert_eq!(t.get("c"), None);
        assert_eq!(t.remove("a"), Some(3));
        assert_eq!(t.get("a"), None);
        assert_eq!(t.len(), 1);
    }

    #[test]
    fn grows_and_keeps_everything() {
        let mut t = OaTable::with_capacity(8);
        for i in 0..10_000u64 {
            t.insert(i, i * 7);
        }
        assert_eq!(t.len(), 10_000);
        for i in 0..10_000u64 {
            assert_eq!(t.get(&i), Some(&(i * 7)), "key {i}");
        }
    }

    #[test]
    fn backward_shift_deletion_preserves_lookups() {
        let mut t = OaTable::with_capacity(8);
        for i in 0..1000u64 {
            t.insert(i, i);
        }
        // Remove every third key; everything else must stay findable.
        for i in (0..1000u64).step_by(3) {
            assert_eq!(t.remove(&i), Some(i));
        }
        for i in 0..1000u64 {
            if i % 3 == 0 {
                assert_eq!(t.get(&i), None);
            } else {
                assert_eq!(t.get(&i), Some(&i));
            }
        }
    }

    #[test]
    fn get_mut_mutates() {
        let mut t = OaTable::default();
        t.insert(5u64, 10u64);
        *t.get_mut(&5).unwrap() += 1;
        assert_eq!(t.get(&5), Some(&11));
    }

    #[test]
    fn iter_sees_all() {
        let mut t = OaTable::default();
        for i in 0..100u64 {
            t.insert(i, i);
        }
        let mut seen: Vec<u64> = t.iter().map(|(k, _)| *k).collect();
        seen.sort_unstable();
        assert_eq!(seen, (0..100).collect::<Vec<_>>());
    }

    #[test]
    fn prop_model_equivalence() {
        // Random op sequences agree with std HashMap.
        check::<Vec<(u8, u8, bool)>>("oatable-model", 120, |ops| {
            let mut t = OaTable::default();
            let mut m = HashMap::new();
            for &(k, v, del) in ops {
                if del {
                    assert_eq!(t.remove(&k), m.remove(&k));
                } else {
                    assert_eq!(t.insert(k, v), m.insert(k, v));
                }
                if t.len() != m.len() {
                    return false;
                }
            }
            m.iter().all(|(k, v)| t.get(k) == Some(v))
        });
    }

    #[test]
    fn slot_addressed_entry_points_agree_with_keyed_ones() {
        let mut t = OaTable::with_capacity(16);
        for i in 0..50u64 {
            t.insert(i, i * 3);
        }
        // index_of + entry_at match get.
        for i in 0..50u64 {
            let idx = t.index_of(&i).unwrap();
            let (k, v) = t.entry_at(idx).unwrap();
            assert_eq!((*k, *v), (i, i * 3));
        }
        assert!(t.index_of(&99).is_none());
        // entry_at_mut mutates in place.
        let idx = t.index_of(&7).unwrap();
        *t.entry_at_mut(idx).unwrap().1 += 1;
        assert_eq!(t.get(&7), Some(&22));
        // remove_at removes exactly the addressed entry and preserves the
        // rest (backward shift may refill the slot).
        let idx = t.index_of(&7).unwrap();
        let (k, v) = t.remove_at(idx).unwrap();
        assert_eq!((k, v), (7, 22));
        assert_eq!(t.len(), 49);
        for i in 0..50u64 {
            if i == 7 {
                assert_eq!(t.get(&i), None);
            } else {
                assert!(t.get(&i).is_some(), "key {i} lost by remove_at");
            }
        }
        // Sweep-style removal by slot: drain everything by re-examining
        // the same slot after each removal (backward shift only moves
        // entries toward the slot being drained, never behind the scan).
        let mut removed = 0;
        let mut idx = 0;
        while idx < t.capacity() {
            if t.remove_at(idx).is_some() {
                removed += 1; // same idx may have shifted in a successor
            } else {
                idx += 1;
            }
        }
        assert_eq!(t.len(), 0);
        assert_eq!(removed, 49);
    }

    #[test]
    fn find_slot_by_hash_is_a_keyed_lookup_in_reverse() {
        // Values are "handles"; every entry must be findable from its
        // hash + value predicate, exactly where index_of puts it, across
        // growth and backward-shift churn.
        let mut t: OaTable<u64, u32> = OaTable::with_capacity(8);
        for i in 0..500u64 {
            t.insert(i, i as u32);
        }
        for i in (0..500u64).step_by(3) {
            t.remove(&i);
        }
        for i in 0..500u64 {
            let found = t.find_slot_by_hash(fxhash(&i), |&v| v == i as u32);
            assert_eq!(found, t.index_of(&i), "key {i}");
        }
        // A hash that matches but a predicate that never does: miss.
        assert_eq!(t.find_slot_by_hash(fxhash(&1u64), |_| false), None);
        // insert_hashed with the precomputed hash behaves like insert.
        let mut t2: OaTable<u64, u32> = OaTable::with_capacity(8);
        t2.insert_hashed(fxhash(&7u64), 7, 70);
        assert_eq!(t2.get(&7), Some(&70));
        assert_eq!(
            t2.find_slot_by_hash(fxhash(&7u64), |&v| v == 70),
            t2.index_of(&7)
        );
    }

    #[test]
    fn fxhash_deterministic() {
        assert_eq!(fxhash(&42u64), fxhash(&42u64));
        assert_ne!(fxhash(&42u64), fxhash(&43u64));
        assert_eq!(fxhash("abc"), fxhash("abc"));
    }
}
