//! The fixed-size request/response slot pair (paper §5.3, Fig. 5) with the
//! two-part primary/overflow optimization (§5.3.1).
//!
//! There is one dedicated pair of request/response slots for each
//! (client thread, trustee thread) pair. Only the client writes the request
//! slot; only the trustee writes the response slot. A *ready bit* (toggle)
//! in each header signals new batches: the request slot holds a new batch
//! iff its toggle differs from the last batch the trustee served; the
//! response is complete iff the response toggle equals the request toggle
//! the client last published.
//!
//! ### On the "no atomic instructions" claim
//! Rust's memory model requires atomic *types* for any cross-thread flag,
//! but `AtomicU64::{load(Acquire), store(Release)}` compile to plain `mov`
//! on x86-64 — no `lock` prefix, no fence. This matches the paper's machine
//! code while staying sound (DESIGN.md substitution #7).

use crate::util::vatomic::VAtomicU64;
use std::cell::UnsafeCell;
use std::sync::atomic::Ordering;

/// Bytes in the primary block following the header word. The paper uses a
/// 128-byte primary block; 8 bytes of it are the header.
pub const PRIMARY_BYTES: usize = 120;
/// Bytes in the overflow block (paper: 1024).
pub const OVERFLOW_BYTES: usize = 1024;
/// Default total slot budget quoted by the paper (§5.3): 1152 bytes.
pub const SLOT_BYTES: usize = PRIMARY_BYTES + 8 + OVERFLOW_BYTES;
/// Maximum requests per batch (count field width).
pub const MAX_BATCH: usize = 1 << 14;

/// Packed slot header.
///
/// ```text
/// bit  0      : toggle (ready bit)
/// bit  1      : heap spill flag (payload continues in a heap buffer)
/// bits 2..16  : request count (request slots) / unused (response slots)
/// bits 16..32 : primary payload length
/// bits 32..48 : overflow payload length
/// bits 48..64 : reserved
/// ```
#[derive(Clone, Copy, PartialEq, Eq, Debug)]
pub struct Header(pub u64);

impl Header {
    /// Pack a header. Bounds are hard errors (not `debug_assert`): a
    /// count/length that overflows its field would silently corrupt the
    /// neighbouring fields in a release build, and lengths can originate
    /// from wire-derived sizes. The three asserts cost a couple of
    /// predictable branches on a path that writes a cache line anyway.
    pub fn new(toggle: bool, spill: bool, count: usize, plen: usize, olen: usize) -> Header {
        assert!(count < MAX_BATCH, "batch count {count} overflows header field (max {})", MAX_BATCH - 1);
        assert!(plen <= PRIMARY_BYTES, "primary payload length {plen} exceeds {PRIMARY_BYTES}");
        assert!(olen <= OVERFLOW_BYTES, "overflow payload length {olen} exceeds {OVERFLOW_BYTES}");
        Header(
            toggle as u64
                | (spill as u64) << 1
                | (count as u64) << 2
                | (plen as u64) << 16
                | (olen as u64) << 32,
        )
    }

    #[inline]
    pub fn toggle(self) -> bool {
        self.0 & 1 != 0
    }

    #[inline]
    pub fn spill(self) -> bool {
        self.0 & 2 != 0
    }

    #[inline]
    pub fn count(self) -> usize {
        ((self.0 >> 2) & 0x3fff) as usize
    }

    #[inline]
    pub fn primary_len(self) -> usize {
        ((self.0 >> 16) & 0xffff) as usize
    }

    #[inline]
    pub fn overflow_len(self) -> usize {
        ((self.0 >> 32) & 0xffff) as usize
    }
}

/// One direction of a slot (requests or responses share the same shape).
///
/// Layout places the header + primary block on the first two cache lines
/// and the overflow block on its own lines, so a trustee scanning mostly
/// idle clients touches only the primary lines (§5.3.1).
#[repr(C, align(64))]
pub struct Slot {
    /// Virtual atomic: a plain `AtomicU64` in production builds; under
    /// `--features model` the interleaving explorer can schedule around
    /// every load/store (see `util::vatomic`).
    header: VAtomicU64,
    primary: UnsafeCell<[u8; PRIMARY_BYTES]>,
    overflow: UnsafeCell<[u8; OVERFLOW_BYTES]>,
    /// Heap spill escape hatch: oversized payloads travel out-of-line.
    /// Written by the producer before the header Release-store, consumed by
    /// the receiver after the Acquire-load — same ordering as the blocks.
    /// Carried as disassembled `Vec` parts (ptr, len, **capacity**) so the
    /// receiving side can reassemble the exact allocation and recycle it
    /// in its spill free list instead of freeing it (DESIGN.md,
    /// "Allocation discipline").
    spill_ptr: UnsafeCell<*mut u8>,
    spill_len: UnsafeCell<usize>,
    spill_cap: UnsafeCell<usize>,
}

// SAFETY: the single-writer/single-reader protocol above; all cross-thread
// publication goes through `header` with Release/Acquire ordering.
unsafe impl Sync for Slot {}
// SAFETY: plain memory plus a leaked-Vec spill pointer whose ownership
// moves with the slot; nothing is thread-affine.
unsafe impl Send for Slot {}

impl Default for Slot {
    fn default() -> Self {
        Slot {
            header: VAtomicU64::new(Header::new(false, false, 0, 0, 0).0),
            primary: UnsafeCell::new([0; PRIMARY_BYTES]),
            overflow: UnsafeCell::new([0; OVERFLOW_BYTES]),
            spill_ptr: UnsafeCell::new(std::ptr::null_mut()),
            spill_len: UnsafeCell::new(0),
            spill_cap: UnsafeCell::new(0),
        }
    }
}

impl Slot {
    /// Producer: current header (Relaxed — producer owns the slot between
    /// publishes).
    #[inline]
    pub fn header_relaxed(&self) -> Header {
        Header(self.header.load(Ordering::Relaxed))
    }

    /// Consumer: acquire-load the header.
    #[inline]
    pub fn header_acquire(&self) -> Header {
        Header(self.header.load(Ordering::Acquire))
    }

    /// Producer: publish a batch (Release).
    #[inline]
    pub fn publish(&self, h: Header) {
        self.header.store(h.0, Ordering::Release);
    }

    /// Producer-side mutable view of the payload blocks.
    ///
    /// # Safety
    /// Caller must be the unique producer for this slot and must not be
    /// racing an unconsumed batch.
    #[inline]
    #[allow(clippy::mut_from_ref)]
    pub unsafe fn payload_mut(&self) -> (&mut [u8; PRIMARY_BYTES], &mut [u8; OVERFLOW_BYTES]) {
        // SAFETY: caller contract (unique producer, no unconsumed batch)
        // makes these the only live references to the blocks.
        unsafe { (&mut *self.primary.get(), &mut *self.overflow.get()) }
    }

    /// Consumer-side view of the payload blocks.
    ///
    /// # Safety
    /// Caller must have acquire-observed a header publishing this batch and
    /// the producer must not republish until the consumer is done.
    #[inline]
    pub unsafe fn payload(&self) -> (&[u8; PRIMARY_BYTES], &[u8; OVERFLOW_BYTES]) {
        // SAFETY: caller contract — the acquire-load of the publishing
        // header ordered these bytes, and the producer will not write
        // again until the consumer is done.
        unsafe { (&*self.primary.get(), &*self.overflow.get()) }
    }

    /// Producer: stash a heap spill buffer (a leaked `Vec<u8>`, capacity
    /// preserved); receiver takes ownership via [`Slot::take_spill`] and
    /// may recycle the allocation.
    ///
    /// # Safety
    /// Producer-only, pre-publish.
    pub unsafe fn set_spill(&self, mut buf: Vec<u8>) {
        let ptr = buf.as_mut_ptr();
        let len = buf.len();
        let cap = buf.capacity();
        std::mem::forget(buf);
        // SAFETY: caller contract (unique producer, pre-publish) — no
        // other reference to the spill fields exists until the header
        // Release-store publishes them.
        unsafe {
            *self.spill_ptr.get() = ptr;
            *self.spill_len.get() = len;
            *self.spill_cap.get() = cap;
        }
    }

    /// Consumer: take ownership of the spill buffer (the producer's exact
    /// allocation — reuse it).
    ///
    /// # Safety
    /// Consumer-only, post-acquire of a header with the spill bit set.
    pub unsafe fn take_spill(&self) -> Vec<u8> {
        // SAFETY: caller contract — the acquire-load of a spill-flagged
        // header ordered these fields; ptr/len/cap are the disassembled
        // parts of exactly one leaked `Vec` (set_spill), reclaimed once.
        unsafe {
            let ptr = *self.spill_ptr.get();
            let len = *self.spill_len.get();
            let cap = *self.spill_cap.get();
            *self.spill_ptr.get() = std::ptr::null_mut();
            *self.spill_len.get() = 0;
            *self.spill_cap.get() = 0;
            assert!(!ptr.is_null(), "spill flag set but no spill buffer");
            Vec::from_raw_parts(ptr, len, cap)
        }
    }
}

/// A request/response slot pair for one (client, trustee) edge.
#[repr(C)]
#[derive(Default)]
pub struct SlotPair {
    pub request: Slot,
    pub response: Slot,
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::quickcheck::check;

    #[test]
    fn header_roundtrip_fields() {
        let h = Header::new(true, false, 37, 119, 1000);
        assert!(h.toggle());
        assert!(!h.spill());
        assert_eq!(h.count(), 37);
        assert_eq!(h.primary_len(), 119);
        assert_eq!(h.overflow_len(), 1000);
    }

    #[test]
    fn prop_header_roundtrip() {
        check::<(bool, bool, u16, u8, u16)>("header-pack", 300, |&(t, s, c, p, o)| {
            let c = (c as usize) % MAX_BATCH;
            let p = (p as usize) % (PRIMARY_BYTES + 1);
            let o = (o as usize) % (OVERFLOW_BYTES + 1);
            let h = Header::new(t, s, c, p, o);
            h.toggle() == t
                && h.spill() == s
                && h.count() == c
                && h.primary_len() == p
                && h.overflow_len() == o
        });
    }

    #[test]
    fn slot_layout_sizes() {
        // header (8) + primary (120) = 128-byte primary region, as in §5.3.1
        assert_eq!(std::mem::align_of::<Slot>(), 64);
        assert_eq!(SLOT_BYTES, 1152, "paper's default slot budget");
        let s = std::mem::size_of::<Slot>();
        assert!(s >= SLOT_BYTES, "slot must hold both blocks (got {s})");
    }

    #[test]
    fn header_new_accepts_exact_bounds() {
        // The largest legal value in every field must pack and unpack.
        let h = Header::new(true, true, MAX_BATCH - 1, PRIMARY_BYTES, OVERFLOW_BYTES);
        assert!(h.toggle());
        assert!(h.spill());
        assert_eq!(h.count(), MAX_BATCH - 1);
        assert_eq!(h.primary_len(), PRIMARY_BYTES);
        assert_eq!(h.overflow_len(), OVERFLOW_BYTES);
    }

    #[test]
    #[should_panic(expected = "batch count")]
    fn header_new_rejects_count_overflow() {
        let _ = Header::new(false, false, MAX_BATCH, 0, 0);
    }

    #[test]
    #[should_panic(expected = "primary payload length")]
    fn header_new_rejects_primary_overflow() {
        let _ = Header::new(false, false, 0, PRIMARY_BYTES + 1, 0);
    }

    #[test]
    #[should_panic(expected = "overflow payload length")]
    fn header_new_rejects_overflow_overflow() {
        let _ = Header::new(false, false, 0, 0, OVERFLOW_BYTES + 1);
    }

    #[test]
    fn publish_and_consume() {
        let slot = Slot::default();
        // SAFETY: single-threaded test — this thread is the unique
        // producer and nothing has been published yet.
        unsafe {
            let (p, _o) = slot.payload_mut();
            p[..4].copy_from_slice(&[1, 2, 3, 4]);
        }
        slot.publish(Header::new(true, false, 1, 4, 0));
        let h = slot.header_acquire();
        assert!(h.toggle());
        assert_eq!(h.count(), 1);
        // SAFETY: the batch was published above and nothing republishes.
        let (p, _) = unsafe { slot.payload() };
        assert_eq!(&p[..4], &[1, 2, 3, 4]);
    }

    #[test]
    fn spill_ownership_transfer() {
        let slot = Slot::default();
        let mut data = Vec::with_capacity(8192);
        data.resize(5000, 7u8);
        // SAFETY: unique producer, pre-publish (single-threaded test).
        unsafe { slot.set_spill(data) };
        slot.publish(Header::new(true, true, 1, 0, 0));
        assert!(slot.header_acquire().spill());
        // SAFETY: spill-flagged header observed just above; taken once.
        let back = unsafe { slot.take_spill() };
        assert_eq!(back.len(), 5000);
        assert_eq!(back.capacity(), 8192, "capacity travels for recycling");
        assert!(back.iter().all(|&b| b == 7));
    }

    #[test]
    fn cross_thread_handoff() {
        use std::sync::Arc;
        let pair = Arc::new(SlotPair::default());
        let p2 = pair.clone();
        let t = std::thread::spawn(move || {
            // trustee: wait for request toggle, echo payload into response
            let mut served = false;
            loop {
                let h = p2.request.header_acquire();
                if h.toggle() != served {
                    let n = h.primary_len();
                    // SAFETY: new toggle acquire-observed; the client will
                    // not republish until it sees our response toggle.
                    let bytes = unsafe { p2.request.payload().0[..n].to_vec() };
                    // SAFETY: this thread is the unique response producer.
                    unsafe {
                        p2.response.payload_mut().0[..n].copy_from_slice(&bytes);
                    }
                    p2.response.publish(Header::new(h.toggle(), false, h.count(), n, 0));
                    served = h.toggle();
                    if bytes == [0xFF] {
                        return;
                    }
                }
                std::hint::spin_loop();
                std::thread::yield_now();
            }
        });

        let mut toggle = false;
        for msg in [&[1u8, 2, 3][..], &[9, 8][..], &[0xFF][..]] {
            toggle = !toggle;
            // SAFETY: unique request producer; the previous batch was
            // fully served (we waited for its response echo).
            unsafe {
                pair.request.payload_mut().0[..msg.len()].copy_from_slice(msg);
            }
            pair.request.publish(Header::new(toggle, false, 1, msg.len(), 0));
            // wait for echo
            loop {
                let h = pair.response.header_acquire();
                if h.toggle() == toggle {
                    // SAFETY: response toggle acquire-observed; trustee
                    // publishes nothing further for this batch.
                    let echoed = unsafe { &pair.response.payload().0[..h.primary_len()] };
                    assert_eq!(echoed, msg);
                    break;
                }
                std::thread::yield_now();
            }
        }
        t.join().unwrap();
    }
}
