//! The delegation channel (paper §5.1, §5.3): framing of closure requests,
//! client-side batching, and trustee-side batch service.
//!
//! Each (client thread, trustee thread) pair owns a dedicated
//! [`SlotPair`][slot::SlotPair] in a global [`Matrix`]. Clients append
//! *records* — erased closures — to their request slot; the trustee applies
//! them in submission order and publishes one response per record (zero
//! bytes for fire-and-forget records) in the same order.
//!
//! A record is framed as:
//!
//! ```text
//! 0..8    thunk     unsafe fn(env, prop, args, &mut ResponseWriter)
//! 8..16   prop      *mut u8 — the entrusted property (or runtime object)
//! 16..20  flags     bit0 NO_RESPONSE, bit1 HEAP (payload out-of-line)
//! 20..22  env_len   u16
//! 22..24  arg_len   u16 — serialized `apply_with` argument bytes
//! 24..    env bytes, then arg bytes, padded to 8
//!         (HEAP records instead carry [ptr u64][len u64][cap u64] of an
//!          out-of-line buffer laid out [args_len u64][env][args])
//! ```
//!
//! The 24-byte minimum matches the paper's accounting (fat pointer +
//! property pointer). The closure's captured environment is copied into the
//! slot *by value* and ownership transfers to the trustee (the client
//! forgets it); this is what makes the paper's pass-by-value discipline
//! race-free. Requests fill the 128-byte primary block first, then the
//! 1024-byte overflow block, preserving submission order (§5.3.1); a record
//! too large even for the overflow block travels out-of-line via a heap
//! buffer (flags.HEAP), mirroring the paper's dynamic-allocation escape
//! hatch for oversized responses.
//!
//! ## Allocation discipline (DESIGN.md, "Allocation discipline")
//!
//! The paper's channel is allocation-free by construction; so is the
//! steady state here:
//!
//! - Requests are framed **directly into a per-endpoint outbox arena**
//!   ([`ClientEndpoint::enqueue_framed`] — reserve/commit: header written
//!   with placeholders, arguments serialized in place, lengths patched),
//!   so there is no per-request framing `Vec` and no frame→outbox memcpy.
//! - [`Completion`]s store their captures **inline** (64 bytes, heap
//!   fallback for oversized closures — counted per endpoint) instead of
//!   one `Box<dyn FnOnce>` per response-bearing request.
//! - Out-of-line payloads and response spills are `Vec<u8>`s drawn from
//!   and returned to bounded per-endpoint **free lists** ([`HeapPool`]);
//!   the allocation itself crosses the channel (capacity travels in the
//!   record / slot), so each side's pool is fed by the other's buffers.
//! - The trustee's response buffer and the client's response scratch are
//!   the pre-existing recycled buffers.
//!
//! ## Batching discipline ([`FlushPolicy`])
//!
//! *Enqueued* and *visible to the trustee* are decoupled: requests
//! accumulate in a per-(client, trustee) outbox and are published by an
//! explicit **flush** — on the [`FLUSH_BYTES`]/[`FLUSH_RECORDS`]
//! watermarks, at the end of the worker scheduler's client phase, when a
//! blocking call needs its response, or under [`HEAP_BACKPRESSURE_BYTES`]
//! pressure from queued out-of-line payloads. Per-pair FIFO survives the
//! decoupling: the outbox is FIFO, `try_flush` packs front-to-back, the
//! trustee applies records in batch order, and responses dispatch in the
//! same order. See DESIGN.md ("Flush policy and ordering contract").

pub mod slot;

pub use slot::{Header, Slot, SlotPair, MAX_BATCH, OVERFLOW_BYTES, PRIMARY_BYTES};

use crate::codec::{Wire, WireReader, WireWriter};
use std::collections::VecDeque;

/// Erased request thunk. `env` points at the (possibly unaligned) captured
/// environment; the thunk takes ownership of it. `args` are serialized
/// `apply_with` arguments. The thunk writes exactly one response value into
/// `out` (or nothing for fire-and-forget records).
pub type Thunk = unsafe fn(env: *const u8, prop: *mut u8, args: &[u8], out: &mut ResponseWriter);

pub const FLAG_NO_RESPONSE: u32 = 1 << 0;
pub const FLAG_HEAP: u32 = 1 << 1;

const RECORD_HEADER: usize = 24;
/// Framed size of a HEAP record: header + [ptr u64][len u64][cap u64].
const HEAP_RECORD_LEN: usize = RECORD_HEADER + 24;
/// Largest inline record payload (env+args): must fit the overflow block.
pub const MAX_INLINE_PAYLOAD: usize = OVERFLOW_BYTES - RECORD_HEADER;

/// Inline capture capacity of a [`Completion`] before the heap fallback.
pub const COMPLETION_INLINE_BYTES: usize = 64;

crate::define_inline_fn_once! {
    /// Runs with the decoded response bytes for one request, in order.
    /// [`Completion::none`] for fire-and-forget requests (no bytes on the
    /// wire). Captures up to [`COMPLETION_INLINE_BYTES`] bytes inline; a
    /// larger (or over-aligned) closure falls back to one heap box, which
    /// the owning endpoint counts ([`ClientEndpoint::completion_heap_spills`]).
    pub struct Completion(r: &mut WireReader<'_>);
    inline_bytes = COMPLETION_INLINE_BYTES;
}

// ---------------------------------------------------------------------
// Heap free list
// ---------------------------------------------------------------------

/// Buffers kept per endpoint before excess ones are dropped.
const HEAP_POOL_MAX: usize = 4;
/// A pooled buffer that grew past this capacity is dropped instead of
/// recycled, so one huge payload cannot pin memory forever.
const HEAP_POOL_BUF_MAX: usize = 1 << 20;

/// Bounded free list of heap buffers (out-of-line request payloads and
/// response spills). Client and trustee endpoints each own one; because
/// the *allocation* travels across the channel (capacity rides in the
/// record / slot), each side's pool is naturally fed by buffers the other
/// side allocated, and the steady state allocates nothing.
#[derive(Default)]
pub struct HeapPool {
    bufs: Vec<Vec<u8>>,
    /// Buffers served from the pool vs freshly allocated.
    pub hits: u64,
    pub misses: u64,
}

impl HeapPool {
    /// Check a buffer (cleared, capacity ≥ `cap_hint`) out. A pooled
    /// buffer too small for `cap_hint` is grown up front and counted as
    /// a **miss** — growing is an allocation event, and counting it here
    /// keeps the hit rate honest instead of hiding a realloc inside the
    /// caller's subsequent extend.
    pub fn take(&mut self, cap_hint: usize) -> Vec<u8> {
        match self.bufs.pop() {
            Some(mut b) => {
                b.clear();
                if b.capacity() >= cap_hint {
                    self.hits += 1;
                } else {
                    self.misses += 1;
                    b.reserve(cap_hint);
                }
                b
            }
            None => {
                self.misses += 1;
                Vec::with_capacity(cap_hint)
            }
        }
    }

    /// Return a buffer to the pool (bounded; oversized buffers drop).
    pub fn recycle(&mut self, mut b: Vec<u8>) {
        if self.bufs.len() < HEAP_POOL_MAX && b.capacity() <= HEAP_POOL_BUF_MAX {
            b.clear();
            self.bufs.push(b);
        }
    }

    pub fn len(&self) -> usize {
        self.bufs.len()
    }

    pub fn is_empty(&self) -> bool {
        self.bufs.is_empty()
    }
}

/// Disassemble a `Vec` for by-value travel through a record.
fn vec_into_raw(mut v: Vec<u8>) -> (*mut u8, usize, usize) {
    let ptr = v.as_mut_ptr();
    let len = v.len();
    let cap = v.capacity();
    std::mem::forget(v);
    (ptr, len, cap)
}

// ---------------------------------------------------------------------
// Flush policy (§5.3 batching discipline)
// ---------------------------------------------------------------------

/// Once an outbox holds a full slot's worth of framed bytes there is
/// nothing left to gain from accumulating further — the next publish is
/// already maximal — so the endpoint flushes at this watermark.
pub const FLUSH_BYTES: usize = PRIMARY_BYTES + OVERFLOW_BYTES;

/// Record-count watermark: minimal records are 32 bytes framed, so ~36 of
/// them fill a slot; flushing by count as well keeps pathological streams
/// of tiny records from scanning long outboxes on every enqueue.
pub const FLUSH_RECORDS: usize = 48;

/// Heap-record backpressure: out-of-line payloads are invisible to the
/// byte watermark (the in-slot record is a fixed 48 bytes), so the outbox
/// separately accounts queued heap bytes and flushes (and counts a
/// backpressure hit) beyond this bound.
pub const HEAP_BACKPRESSURE_BYTES: usize = 256 * 1024;

/// Once this many consumed bytes accumulate at the front of the outbox
/// arena, the unconsumed tail is compacted to offset zero (a bounded
/// memmove, instead of either compacting per flush or growing forever).
const ARENA_COMPACT_BYTES: usize = 4096;

/// When a client endpoint publishes its outbox (paper §5.3 batching).
///
/// * `Eager` — publish after every enqueue (the pre-refactor behaviour):
///   lowest latency per request, but batches degenerate to size 1 whenever
///   the trustee keeps up, forfeiting the paper's amortization win.
/// * `Adaptive` — accumulate per (client, trustee) outbox and publish on
///   (a) the byte/record watermarks above, (b) the end of the scheduler's
///   client phase, or (c) a blocking call that needs the response.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum FlushPolicy {
    Eager,
    #[default]
    Adaptive,
}

impl FlushPolicy {
    /// Parse a CLI spec (`eager` | `adaptive`).
    pub fn from_spec(s: &str) -> FlushPolicy {
        match s {
            "eager" => FlushPolicy::Eager,
            "adaptive" | "batched" => FlushPolicy::Adaptive,
            other => panic!("unknown flush policy {other:?} (want eager|adaptive)"),
        }
    }

    pub fn label(&self) -> &'static str {
        match self {
            FlushPolicy::Eager => "eager",
            FlushPolicy::Adaptive => "adaptive",
        }
    }
}

/// All slot pairs for an `n`-worker runtime. `pair(c, t)` is written by
/// client `c` and served by trustee `t`.
pub struct Matrix {
    n: usize,
    cells: Vec<SlotPair>,
}

impl Matrix {
    pub fn new(n: usize) -> Matrix {
        let mut cells = Vec::new();
        cells.resize_with(n * n, SlotPair::default);
        Matrix { n, cells }
    }

    pub fn n(&self) -> usize {
        self.n
    }

    #[inline]
    pub fn pair(&self, client: usize, trustee: usize) -> &SlotPair {
        &self.cells[client * self.n + trustee]
    }
}

/// Per-record outbox metadata; the framed bytes live in the endpoint's
/// contiguous arena.
struct OutRecord {
    /// Padded framed length in the arena (records are ≤ the overflow
    /// block, so u32 is ample).
    len: u32,
    /// Bytes of the out-of-line heap payload (0 for inline records).
    heap_len: usize,
    completion: Completion,
}

/// Client side of one (client, trustee) edge: outbox, in-flight batch, and
/// response dispatch.
///
/// *Enqueued* is decoupled from *visible to the trustee*: requests
/// accumulate in the outbox until a flush publishes them into the slot
/// (watermark / phase-end / blocking call — see [`FlushPolicy`]). Per-pair
/// FIFO is preserved because the outbox is FIFO, batches pack front to
/// back, and the trustee serves records in batch order.
///
/// The outbox is a contiguous byte **arena** plus a metadata deque:
/// [`ClientEndpoint::enqueue_framed`] frames each record in place
/// (reserve/commit) and [`ClientEndpoint::try_flush`] copies a front
/// window of the arena into the slot — the only copy a request pays.
pub struct ClientEndpoint {
    /// Toggle of the last published batch.
    toggle: bool,
    /// A batch is in flight (published, response not yet consumed).
    awaiting: bool,
    inflight: VecDeque<Completion>,
    /// Empty deque swapped with `inflight` during poll so completion
    /// capacity is recycled.
    spare_inflight: VecDeque<Completion>,
    /// Response batches consumed from the slot but not yet dispatched:
    /// spin-waiting callers ([`Self::poll_detach`]) park batches here so
    /// the next regular poll dispatches them, in order, from a safe
    /// context.
    deferred: VecDeque<ResponseBatch>,
    /// Framed records, back to back (recycled; grows to the high-water
    /// mark of queued bytes and stays).
    arena: Vec<u8>,
    /// Consumed (already published) prefix of `arena`.
    arena_cursor: usize,
    records: VecDeque<OutRecord>,
    /// Out-of-line heap payload bytes queued (backpressure accounting).
    outbox_heap_bytes: usize,
    /// Free list feeding out-of-line request payloads; refilled by
    /// response-spill buffers taken from the slot.
    pub heap_pool: HeapPool,
    scratch: Vec<u8>,
    /// Stats: requests enqueued / batches published / responses dispatched.
    pub sent: u64,
    pub batches: u64,
    pub completed: u64,
    /// Requests carried by published batches (occupancy numerator; the
    /// denominator is `batches`).
    pub flushed_requests: u64,
    /// Batches published while the queued heap-payload bytes were at or
    /// past [`HEAP_BACKPRESSURE_BYTES`] (the bound is advisory — it forces
    /// publishes, it cannot block a producer that keeps enqueueing while a
    /// batch is in flight).
    pub backpressure_hits: u64,
    /// Hot-path allocation events: completions whose captures exceeded
    /// the inline budget and fell back to a heap box.
    pub completion_heap_spills: u64,
    /// Records whose payload went out-of-line (the heap escape hatch).
    pub heap_records: u64,
    /// Bytes memcpy'd into request slots (the one copy a request pays).
    pub slot_bytes_copied: u64,
}

impl Default for ClientEndpoint {
    fn default() -> Self {
        ClientEndpoint {
            toggle: false,
            awaiting: false,
            inflight: VecDeque::new(),
            spare_inflight: VecDeque::new(),
            deferred: VecDeque::new(),
            arena: Vec::new(),
            arena_cursor: 0,
            records: VecDeque::new(),
            outbox_heap_bytes: 0,
            heap_pool: HeapPool::default(),
            scratch: Vec::new(),
            sent: 0,
            batches: 0,
            completed: 0,
            flushed_requests: 0,
            backpressure_hits: 0,
            completion_heap_spills: 0,
            heap_records: 0,
            slot_bytes_copied: 0,
        }
    }
}

impl ClientEndpoint {
    /// Frame a request directly into the outbox arena (reserve/commit)
    /// and queue it. The request is not visible to the trustee until a
    /// flush publishes it.
    ///
    /// `write_args` serializes the `apply_with` argument bytes straight
    /// into the arena (pass `|_| {}` for none). Whether the record expects
    /// a response is derived from the completion: [`Completion::none`]
    /// frames a fire-and-forget record.
    ///
    /// # Safety contract (enforced by the `trust` layer)
    /// `thunk` must interpret `env`/`args`/`prop` with the same types used
    /// to frame them here, and `env` must be the by-value bytes of a
    /// closure the caller has `mem::forget`-ed (ownership moves here).
    pub fn enqueue_framed(
        &mut self,
        thunk: Thunk,
        prop: *mut u8,
        env: &[u8],
        completion: Completion,
        write_args: impl FnOnce(&mut WireWriter),
    ) {
        let no_response = completion.is_none();
        assert!(env.len() <= u16::MAX as usize, "closure env too large");
        let start = self.arena.len();
        // Panic safety: `write_args` runs user serialization code. If it
        // unwinds, the guard puts the buffer back truncated to `start`,
        // so the endpoint's arena/records/cursor stay coherent (the
        // half-framed record is simply discarded) and Drop-time heap
        // reclamation still walks a well-formed arena.
        struct ArenaRestore<'a> {
            arena: &'a mut Vec<u8>,
            start: usize,
            w: Option<WireWriter>,
        }
        impl Drop for ArenaRestore<'_> {
            fn drop(&mut self) {
                if let Some(w) = self.w.take() {
                    let mut buf = w.into_vec();
                    buf.truncate(self.start);
                    *self.arena = buf;
                }
            }
        }
        let taken = std::mem::take(&mut self.arena);
        let mut guard =
            ArenaRestore { arena: &mut self.arena, start, w: Some(WireWriter::append(taken)) };
        let w = guard.w.as_mut().unwrap();
        w.put_bytes(&(thunk as usize as u64).to_le_bytes());
        w.put_bytes(&(prop as usize as u64).to_le_bytes());
        let flags_at = w.len();
        w.put_bytes(&0u32.to_le_bytes()); // flags, patched below
        w.put_bytes(&(env.len() as u16).to_le_bytes());
        let arg_len_at = w.len();
        w.put_bytes(&0u16.to_le_bytes()); // arg_len, patched below
        w.put_bytes(env);
        let args_at = w.len();
        // Commit phase: serialize args in place, then patch the header.
        write_args(w);
        let mut buf = guard.w.take().unwrap().into_vec();
        drop(guard);
        let arg_len = buf.len() - args_at;
        let payload = env.len() + arg_len;
        let mut flags = if no_response { FLAG_NO_RESPONSE } else { 0 };
        let heap_len = if payload > MAX_INLINE_PAYLOAD {
            // Escape hatch: move the payload out of line. The heap buffer
            // is [args_len u64][env][args]; the record body carries the
            // buffer's (ptr, len, cap) so the trustee can reassemble the
            // exact Vec and recycle it.
            flags |= FLAG_HEAP;
            let mut hb = self.heap_pool.take(payload + 8);
            hb.extend_from_slice(&(arg_len as u64).to_le_bytes());
            hb.extend_from_slice(&buf[start + RECORD_HEADER..]);
            buf.truncate(start + RECORD_HEADER); // keep header; arg_len stays 0
            let (ptr, len, cap) = vec_into_raw(hb);
            buf.extend_from_slice(&(ptr as usize as u64).to_le_bytes());
            buf.extend_from_slice(&(len as u64).to_le_bytes());
            buf.extend_from_slice(&(cap as u64).to_le_bytes());
            self.heap_records += 1;
            payload + 8
        } else {
            assert!(arg_len <= u16::MAX as usize);
            buf[arg_len_at..arg_len_at + 2].copy_from_slice(&(arg_len as u16).to_le_bytes());
            0
        };
        buf[flags_at..flags_at + 4].copy_from_slice(&flags.to_le_bytes());
        // Pad to 8 so successive records stay 8-aligned.
        while buf.len() % 8 != 0 {
            buf.push(0);
        }
        let rec_len = buf.len() - start;
        debug_assert!(rec_len <= RECORD_HEADER + MAX_INLINE_PAYLOAD + 7);
        self.arena = buf;
        self.outbox_heap_bytes += heap_len;
        if completion.was_boxed() {
            self.completion_heap_spills += 1;
        }
        self.records.push_back(OutRecord { len: rec_len as u32, heap_len, completion });
        self.sent += 1;
    }

    /// Framed bytes queued in the outbox (watermark accounting).
    fn outbox_bytes(&self) -> usize {
        self.arena.len() - self.arena_cursor
    }

    /// Should the adaptive policy publish now rather than wait for the
    /// phase-end flush?
    pub fn wants_flush(&self) -> bool {
        self.outbox_bytes() >= FLUSH_BYTES
            || self.records.len() >= FLUSH_RECORDS
            || self.over_heap_bound()
    }

    /// Are the queued out-of-line payload bytes at or past the (advisory)
    /// backpressure bound?
    pub fn over_heap_bound(&self) -> bool {
        self.outbox_heap_bytes >= HEAP_BACKPRESSURE_BYTES
    }

    /// Number of requests not yet responded to (outbox + in flight +
    /// detached-but-undispatched).
    pub fn pending(&self) -> usize {
        self.records.len()
            + self.inflight.len()
            + self.deferred.iter().map(|b| b.len()).sum::<usize>()
    }

    /// Requests enqueued but not yet published to the trustee.
    pub fn queued(&self) -> usize {
        self.records.len()
    }

    pub fn has_inflight(&self) -> bool {
        self.awaiting
    }

    /// If no batch is in flight and the outbox is non-empty, pack a batch
    /// into the request slot and publish it. Returns requests flushed.
    pub fn try_flush(&mut self, pair: &SlotPair) -> usize {
        if self.awaiting || self.records.is_empty() {
            return 0;
        }
        let over_heap_at_entry = self.over_heap_bound();
        // SAFETY: we are the unique producer and no batch is in flight.
        let (primary, overflow) = unsafe { pair.request.payload_mut() };
        let mut pcur = 0usize;
        let mut ocur = 0usize;
        let mut in_overflow = false;
        let mut count = 0usize;
        loop {
            let len = match self.records.front() {
                Some(r) => r.len as usize,
                None => break,
            };
            if count + 1 >= MAX_BATCH {
                break;
            }
            let src = &self.arena[self.arena_cursor..self.arena_cursor + len];
            // Primary first; once a record spills to overflow, all later
            // records in the batch follow it (preserves submission order).
            if !in_overflow && pcur + len <= PRIMARY_BYTES {
                primary[pcur..pcur + len].copy_from_slice(src);
                pcur += len;
            } else if ocur + len <= OVERFLOW_BYTES {
                in_overflow = true;
                overflow[ocur..ocur + len].copy_from_slice(src);
                ocur += len;
            } else {
                break;
            }
            self.arena_cursor += len;
            let rec = self.records.pop_front().unwrap();
            self.outbox_heap_bytes -= rec.heap_len;
            self.inflight.push_back(rec.completion);
            count += 1;
        }
        debug_assert!(count > 0, "outbox head must fit an empty overflow block");
        self.slot_bytes_copied += (pcur + ocur) as u64;
        // Reclaim consumed arena space: free reset when drained, bounded
        // compaction otherwise.
        if self.records.is_empty() {
            self.arena.clear();
            self.arena_cursor = 0;
        } else if self.arena_cursor >= ARENA_COMPACT_BYTES {
            self.arena.copy_within(self.arena_cursor.., 0);
            let keep = self.arena.len() - self.arena_cursor;
            self.arena.truncate(keep);
            self.arena_cursor = 0;
        }
        if over_heap_at_entry {
            // This publish was forced by (and relieves) heap-byte pressure.
            self.backpressure_hits += 1;
        }
        self.toggle = !self.toggle;
        pair.request
            .publish(Header::new(self.toggle, false, count, pcur, ocur));
        self.awaiting = true;
        self.batches += 1;
        self.flushed_requests += count as u64;
        count
    }

    /// If the in-flight batch completed, detach its response bytes and
    /// completions as a [`ResponseBatch`] and clear the in-flight state.
    /// The caller dispatches the batch *without holding this endpoint* (so
    /// completions may freely re-enter the worker and enqueue follow-up
    /// requests) and then returns the buffers via [`Self::finish_poll`].
    pub fn begin_poll(&mut self, pair: &SlotPair) -> Option<ResponseBatch> {
        if !self.awaiting {
            return None;
        }
        let h = pair.response.header_acquire();
        if h.toggle() != self.toggle {
            return None;
        }
        // SAFETY: trustee published this batch's responses and will not
        // rewrite them until we publish the next request batch.
        let (p, o) = unsafe { pair.response.payload() };
        let plen = h.primary_len();
        let olen = h.overflow_len();
        let mut bytes = std::mem::take(&mut self.scratch);
        bytes.clear();
        bytes.extend_from_slice(&p[..plen]);
        bytes.extend_from_slice(&o[..olen]);
        if h.spill() {
            // SAFETY: header published with the spill bit; we own it now.
            let spill = unsafe { pair.response.take_spill() };
            bytes.extend_from_slice(&spill);
            // The trustee's allocation refills our request-payload pool.
            self.heap_pool.recycle(spill);
        }
        let completions =
            std::mem::replace(&mut self.inflight, std::mem::take(&mut self.spare_inflight));
        self.awaiting = false;
        Some(ResponseBatch { bytes, completions })
    }

    /// Return the buffers from a dispatched [`ResponseBatch`], account the
    /// completions, and publish the next batch if one is queued.
    pub fn finish_poll(
        &mut self,
        pair: &SlotPair,
        dispatched: usize,
        scratch: Vec<u8>,
        spare: VecDeque<Completion>,
    ) {
        self.completed += dispatched as u64;
        self.scratch = scratch;
        if self.spare_inflight.capacity() < spare.capacity() {
            self.spare_inflight = spare;
        }
        self.try_flush(pair);
    }

    /// Consume a completed response batch **without dispatching its
    /// completions**: the batch is parked on the deferred queue (drained,
    /// in submission order, by the next regular poll) and the next request
    /// batch is published. Spin-waiting callers (the clone ack) use this
    /// so the edge keeps moving while no foreign completion — which could
    /// re-enter user code from an unsafe context — ever runs under them.
    /// Returns true if the edge made progress (batch consumed or batch
    /// published).
    pub fn poll_detach(&mut self, pair: &SlotPair) -> bool {
        match self.begin_poll(pair) {
            Some(batch) => {
                self.deferred.push_back(batch);
                self.try_flush(pair);
                true
            }
            None => self.try_flush(pair) > 0,
        }
    }

    /// Next parked batch awaiting dispatch, oldest first (see
    /// [`Self::poll_detach`]). Callers must dispatch deferred batches
    /// before any [`Self::begin_poll`] batch to keep FIFO dispatch order.
    pub fn pop_deferred(&mut self) -> Option<ResponseBatch> {
        self.deferred.pop_front()
    }

    /// Single-call convenience used by loopback tests and simple drivers:
    /// poll, dispatch completions in order, flush the next batch. Returns
    /// completions dispatched. The worker scheduler uses the split
    /// [`Self::begin_poll`] / [`Self::finish_poll`] form instead so that
    /// completions run outside any endpoint borrow.
    pub fn poll(&mut self, pair: &SlotPair) -> usize {
        let mut total = 0;
        while let Some(batch) = self.deferred.pop_front() {
            let (n, scratch, spare) = batch.dispatch();
            self.finish_poll(pair, n, scratch, spare);
            total += n;
        }
        match self.begin_poll(pair) {
            None => {
                self.try_flush(pair);
            }
            Some(batch) => {
                let (n, scratch, spare) = batch.dispatch();
                self.finish_poll(pair, n, scratch, spare);
                total += n;
            }
        }
        total
    }
}

impl Drop for ClientEndpoint {
    fn drop(&mut self) {
        // Unpublished HEAP records still own their out-of-line buffers
        // through raw parts embedded in the arena; reassemble and free
        // them (completions free themselves via their own Drop).
        let mut cur = self.arena_cursor;
        while let Some(rec) = self.records.pop_front() {
            if rec.heap_len > 0 {
                let body = &self.arena[cur + RECORD_HEADER..cur + HEAP_RECORD_LEN];
                let ptr = u64::from_le_bytes(body[0..8].try_into().unwrap()) as usize as *mut u8;
                let len = u64::from_le_bytes(body[8..16].try_into().unwrap()) as usize;
                let cap = u64::from_le_bytes(body[16..24].try_into().unwrap()) as usize;
                // SAFETY: framed by enqueue_framed from a forgotten Vec and
                // never handed to a trustee.
                drop(unsafe { Vec::from_raw_parts(ptr, len, cap) });
            }
            cur += rec.len as usize;
        }
    }
}

/// One completed batch's response bytes + completions, detached from the
/// endpoint so dispatch can run without borrowing it.
pub struct ResponseBatch {
    bytes: Vec<u8>,
    completions: VecDeque<Completion>,
}

impl ResponseBatch {
    /// Number of requests this batch answers.
    pub fn len(&self) -> usize {
        self.completions.len()
    }

    pub fn is_empty(&self) -> bool {
        self.completions.is_empty()
    }

    /// Run every completion in submission order over the response stream.
    /// Returns (dispatched, scratch buffer, drained deque) for
    /// [`ClientEndpoint::finish_poll`].
    pub fn dispatch(self) -> (usize, Vec<u8>, VecDeque<Completion>) {
        let ResponseBatch { bytes, mut completions } = self;
        let mut dispatched = 0;
        {
            let mut reader = WireReader::new(&bytes);
            while let Some(completion) = completions.pop_front() {
                completion.call(&mut reader);
                dispatched += 1;
            }
            debug_assert!(
                reader.is_empty(),
                "response bytes not fully consumed: {} left",
                reader.remaining()
            );
        }
        (dispatched, bytes, completions)
    }
}

/// Writes the response stream for one batch. Fixed-size values are written
/// raw; variable-size values are preceded by their size (§5.3).
pub struct ResponseWriter {
    out: WireWriter,
}

impl Default for ResponseWriter {
    fn default() -> Self {
        Self::new()
    }
}

impl ResponseWriter {
    pub fn new() -> ResponseWriter {
        ResponseWriter { out: WireWriter::new() }
    }

    pub fn reuse(buf: Vec<u8>) -> ResponseWriter {
        ResponseWriter { out: WireWriter::reuse(buf) }
    }

    /// Append one response value.
    pub fn write_value<U: Wire>(&mut self, u: &U) {
        if U::FIXED_SIZE.is_none() {
            // Length prefix lets the client-side reader skip/validate.
            self.out.put_varint(u.encoded_size() as u64);
        }
        u.write(&mut self.out);
    }

    /// Append an `Option<&[u8]>` response **without owning the bytes** —
    /// wire-compatible with `read_response::<Option<Vec<u8>>>` (and with
    /// the borrowing [`read_opt_bytes`]) on the consuming side. This is
    /// the one-copy GET path: the value moves store → response buffer
    /// here, and response stream → wire buffer in the completion, with no
    /// intermediate owned `Vec`.
    pub fn write_opt_bytes(&mut self, v: Option<&[u8]>) {
        match v {
            None => {
                self.out.put_varint(1); // outer size: just the tag
                self.out.put_u8(0);
            }
            Some(b) => {
                let inner = 1 + crate::codec::varint_len(b.len() as u64) + b.len();
                self.out.put_varint(inner as u64);
                self.out.put_u8(1);
                self.out.put_varint(b.len() as u64);
                self.out.put_bytes(b);
            }
        }
    }

    pub fn len(&self) -> usize {
        self.out.len()
    }

    pub fn is_empty(&self) -> bool {
        self.out.is_empty()
    }

    /// Take back the underlying buffer without publishing (trustee-local
    /// shortcut paths that bounce the response through scratch).
    pub fn into_inner(self) -> Vec<u8> {
        self.out.into_vec()
    }

    /// Publish the accumulated responses into the response slot; an
    /// oversized stream spills into a buffer drawn from `spill_pool`.
    /// Returns the scratch buffer for reuse.
    pub fn publish(
        self,
        pair: &SlotPair,
        toggle: bool,
        count: usize,
        spill_pool: &mut HeapPool,
    ) -> Vec<u8> {
        let bytes = self.out.into_vec();
        // SAFETY: trustee is the unique producer of the response slot and
        // the previous batch was consumed (client republished requests).
        let (p, o) = unsafe { pair.response.payload_mut() };
        let n = bytes.len();
        let plen = n.min(PRIMARY_BYTES);
        p[..plen].copy_from_slice(&bytes[..plen]);
        let rest = &bytes[plen..];
        let olen = rest.len().min(OVERFLOW_BYTES);
        o[..olen].copy_from_slice(&rest[..olen]);
        let spill_bytes = &rest[olen..];
        let spill = !spill_bytes.is_empty();
        if spill {
            let mut sb = spill_pool.take(spill_bytes.len());
            sb.extend_from_slice(spill_bytes);
            // SAFETY: producer-side, pre-publish.
            unsafe { pair.response.set_spill(sb) };
        }
        pair.response
            .publish(Header::new(toggle, spill, count, plen, olen));
        bytes // returned for buffer reuse
    }
}

/// Read one response value the way the client dispatch does.
pub fn read_response<U: Wire>(r: &mut WireReader<'_>) -> U {
    if U::FIXED_SIZE.is_none() {
        let len = r.get_varint().expect("response length") as usize;
        let bytes = r.take(len).expect("response bytes");
        let mut sub = WireReader::new(bytes);
        return U::read(&mut sub).expect("response decode");
    }
    U::read(r).expect("response decode")
}

/// Read one `Option<&[u8]>` response written by
/// [`ResponseWriter::write_opt_bytes`] (or by `write_value` of an
/// `Option<Vec<u8>>`), **borrowing** the bytes from the response stream
/// instead of allocating a `Vec` — the client half of the one-copy GET.
pub fn read_opt_bytes<'a>(r: &mut WireReader<'a>) -> Option<&'a [u8]> {
    let len = r.get_varint().expect("response length") as usize;
    let bytes = r.take(len).expect("response bytes");
    let mut sub = WireReader::new(bytes);
    match sub.get_u8().expect("option tag") {
        0 => None,
        1 => {
            let n = sub.get_varint().expect("value length") as usize;
            Some(sub.take(n).expect("value bytes"))
        }
        t => panic!("bad option tag {t} in byte response"),
    }
}

/// Trustee side of one (client, trustee) edge.
#[derive(Default)]
pub struct TrusteeEndpoint {
    last_served: bool,
    resp_buf: Vec<u8>,
    /// Free list feeding response spills; refilled by out-of-line request
    /// payload buffers taken from served records.
    pub heap_pool: HeapPool,
    /// Stats.
    pub served_batches: u64,
    pub served_requests: u64,
    /// Bytes memcpy'd into response slots.
    pub slot_bytes_copied: u64,
}

impl TrusteeEndpoint {
    /// Serve a pending batch, if any: apply every record in order and
    /// publish the responses. Returns records processed.
    ///
    /// # Safety
    /// Every record in the slot must have been framed by
    /// [`ClientEndpoint::enqueue_framed`] with a thunk whose types match
    /// the framed payload, and `prop` pointers must be live objects owned
    /// by this trustee thread.
    pub unsafe fn serve(&mut self, pair: &SlotPair) -> usize {
        let h = pair.request.header_acquire();
        if h.toggle() == self.last_served {
            return 0;
        }
        let count = h.count();
        // SAFETY: client published this batch and won't touch the payload
        // until we publish the response.
        let (p, o) = unsafe { pair.request.payload() };
        let mut rw = ResponseWriter::reuse(std::mem::take(&mut self.resp_buf));
        let mut served = 0;
        let mut region: &[u8] = &p[..h.primary_len()];
        let mut cur = 0usize;
        let mut in_overflow = false;
        while served < count {
            if cur >= region.len() {
                assert!(!in_overflow, "batch count exceeds payload");
                region = &o[..h.overflow_len()];
                cur = 0;
                in_overflow = true;
                continue;
            }
            // SAFETY: serve()'s contract covers the whole batch — every record
            // was framed by a client endpoint with matching thunk/env/prop.
            cur += unsafe { Self::apply_record(&region[cur..], &mut rw, &mut self.heap_pool) };
            cur = (cur + 7) & !7;
            served += 1;
        }
        self.slot_bytes_copied += rw.len().min(PRIMARY_BYTES + OVERFLOW_BYTES) as u64;
        self.resp_buf = rw.publish(pair, h.toggle(), count, &mut self.heap_pool);
        self.last_served = h.toggle();
        self.served_batches += 1;
        self.served_requests += served as u64;
        served
    }

    /// Serve a pending batch only if *every* record's thunk is admitted by
    /// `admit`; otherwise apply nothing and return 0, leaving the batch for
    /// a later unconditional [`TrusteeEndpoint::serve`].
    ///
    /// This is the clone-ack spin's cycle breaker: the trust layer admits
    /// only its refcount-increment thunks, which touch nothing but the
    /// property header, so such a batch is safe to apply re-entrantly while
    /// a delegated closure is still on the stack (see
    /// `runtime::serve_rc_increment_batches`). The pre-scan walks record
    /// headers without taking ownership of anything — heap payloads stay
    /// intact for the eventual real serve when the batch is rejected.
    ///
    /// # Safety
    /// Same contract as [`TrusteeEndpoint::serve`].
    pub unsafe fn serve_filtered(&mut self, pair: &SlotPair, admit: fn(u64) -> bool) -> usize {
        let h = pair.request.header_acquire();
        if h.toggle() == self.last_served {
            return 0;
        }
        let count = h.count();
        // SAFETY: client published this batch and won't touch the payload
        // until we publish the response.
        let (p, o) = unsafe { pair.request.payload() };
        let mut region: &[u8] = &p[..h.primary_len()];
        let mut cur = 0usize;
        let mut in_overflow = false;
        let mut seen = 0usize;
        while seen < count {
            if cur >= region.len() {
                if in_overflow {
                    // Malformed count: let the real serve's assert report it.
                    break;
                }
                region = &o[..h.overflow_len()];
                cur = 0;
                in_overflow = true;
                continue;
            }
            let rec = &region[cur..];
            let thunk_raw = u64::from_le_bytes(rec[0..8].try_into().unwrap());
            if !admit(thunk_raw) {
                return 0;
            }
            cur += Self::record_len(rec);
            cur = (cur + 7) & !7;
            seen += 1;
        }
        // Every record admitted: serve the batch for real.
        // SAFETY: forwarded from serve_filtered's own contract — same batch,
        // same framing invariants.
        unsafe { self.serve(pair) }
    }

    /// Unpadded length of the record starting at `rec[0]` (header inspection
    /// only; takes no ownership).
    fn record_len(rec: &[u8]) -> usize {
        let flags = u32::from_le_bytes(rec[16..20].try_into().unwrap());
        if flags & FLAG_HEAP != 0 {
            return HEAP_RECORD_LEN;
        }
        let env_len = u16::from_le_bytes(rec[20..22].try_into().unwrap()) as usize;
        let arg_len = u16::from_le_bytes(rec[22..24].try_into().unwrap()) as usize;
        RECORD_HEADER + env_len + arg_len
    }

    /// Apply a single record starting at `rec[0]`; returns its unpadded
    /// length within the region.
    ///
    /// # Safety
    ///
    /// `rec` must start a record framed by a client endpoint: the thunk
    /// word is a real [`Thunk`], env/prop satisfy that thunk's contract,
    /// and heap records carry the parts of a live `Vec`.
    unsafe fn apply_record(rec: &[u8], rw: &mut ResponseWriter, pool: &mut HeapPool) -> usize {
        let thunk_raw = u64::from_le_bytes(rec[0..8].try_into().unwrap());
        let prop = u64::from_le_bytes(rec[8..16].try_into().unwrap()) as usize as *mut u8;
        let flags = u32::from_le_bytes(rec[16..20].try_into().unwrap());
        let env_len = u16::from_le_bytes(rec[20..22].try_into().unwrap()) as usize;
        let arg_len = u16::from_le_bytes(rec[22..24].try_into().unwrap()) as usize;
        // SAFETY: thunk was framed from a real fn pointer in this binary.
        let thunk: Thunk = unsafe { std::mem::transmute::<usize, Thunk>(thunk_raw as usize) };
        if flags & FLAG_HEAP != 0 {
            let ptr = u64::from_le_bytes(rec[24..32].try_into().unwrap()) as usize as *mut u8;
            let len = u64::from_le_bytes(rec[32..40].try_into().unwrap()) as usize;
            let cap = u64::from_le_bytes(rec[40..48].try_into().unwrap()) as usize;
            // SAFETY: ownership of the heap buffer transfers to us; the
            // client disassembled a live Vec with exactly these parts.
            let heap = unsafe { Vec::from_raw_parts(ptr, len, cap) };
            let args_len = u64::from_le_bytes(heap[0..8].try_into().unwrap()) as usize;
            let env = &heap[8..8 + env_len];
            let args = &heap[8 + env_len..8 + env_len + args_len];
            // SAFETY: thunk/env/prop come from the framed record; the framer
            // guarantees they satisfy the thunk's contract (see # Safety).
            unsafe { thunk(env.as_ptr(), prop, args, rw) };
            // The client's allocation refills our spill pool.
            pool.recycle(heap);
            return HEAP_RECORD_LEN;
        }
        let env = &rec[RECORD_HEADER..RECORD_HEADER + env_len];
        let args = &rec[RECORD_HEADER + env_len..RECORD_HEADER + env_len + arg_len];
        // SAFETY: thunk/env/prop come from the framed record; the framer
        // guarantees they satisfy the thunk's contract (see # Safety).
        unsafe { thunk(env.as_ptr(), prop, args, rw) };
        RECORD_HEADER + env_len + arg_len
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::cell::Cell;
    use std::rc::Rc;

    /// Thunk: increment a u64 property by the u64 captured in env, respond
    /// with the pre-increment value (fetch-and-add).
    ///
    /// # Safety
    /// `env` holds a framed `u64` delta; `prop` points at the test's live
    /// `u64` accumulator.
    unsafe fn fadd_thunk(env: *const u8, prop: *mut u8, _args: &[u8], out: &mut ResponseWriter) {
        // SAFETY: env is the framed u64 delta.
        let delta = unsafe { env.cast::<u64>().read_unaligned() };
        let p = prop.cast::<u64>();
        // SAFETY: prop is the test's live u64; thunks run serially.
        let old = unsafe { *p };
        // SAFETY: same pointer as the read above.
        unsafe { *p = old + delta };
        out.write_value(&old);
    }

    /// Fire-and-forget thunk: add without responding.
    ///
    /// # Safety
    /// `env` holds a framed `u64` delta; `prop` points at the test's live
    /// `u64` accumulator.
    unsafe fn add_thunk(env: *const u8, prop: *mut u8, _args: &[u8], _out: &mut ResponseWriter) {
        // SAFETY: env is the framed u64 delta.
        let delta = unsafe { env.cast::<u64>().read_unaligned() };
        // SAFETY: prop is the test's live u64 accumulator.
        unsafe { *prop.cast::<u64>() += delta };
    }

    /// Thunk with serialized args: append a string length.
    ///
    /// # Safety
    /// `prop` points at the test's live `u64`; `args` carry a wire string.
    unsafe fn arg_thunk(_env: *const u8, prop: *mut u8, args: &[u8], out: &mut ResponseWriter) {
        let mut r = WireReader::new(args);
        let s = String::read(&mut r).unwrap();
        // SAFETY: prop is the test's live u64 accumulator.
        unsafe { *prop.cast::<u64>() += s.len() as u64 };
        out.write_value(&s.to_uppercase());
    }

    fn enqueue_fadd(ep: &mut ClientEndpoint, prop: *mut u64, delta: u64, completion: Completion) {
        ep.enqueue_framed(
            fadd_thunk,
            prop as *mut u8,
            &delta.to_le_bytes(),
            completion,
            |_| {},
        );
    }

    #[test]
    fn loopback_single_request() {
        let pair = SlotPair::default();
        let mut client = ClientEndpoint::default();
        let mut trustee = TrusteeEndpoint::default();
        let mut counter: u64 = 100;

        let got = Rc::new(Cell::new(0u64));
        let g = got.clone();
        enqueue_fadd(
            &mut client,
            &mut counter,
            5,
            Completion::new(move |r| g.set(read_response::<u64>(r))),
        );
        assert_eq!(client.try_flush(&pair), 1);
        // SAFETY: every record was framed above with matching thunk/env/prop.
        assert_eq!(unsafe { trustee.serve(&pair) }, 1);
        assert_eq!(client.poll(&pair), 1);
        assert_eq!(got.get(), 100);
        assert_eq!(counter, 105);
        assert_eq!(client.pending(), 0);
        assert_eq!(
            client.completion_heap_spills, 0,
            "an Rc-captured completion must store inline"
        );
    }

    #[test]
    fn batch_packs_multiple_and_preserves_order() {
        let pair = SlotPair::default();
        let mut client = ClientEndpoint::default();
        let mut trustee = TrusteeEndpoint::default();
        let mut counter: u64 = 0;

        let order = Rc::new(std::cell::RefCell::new(Vec::new()));
        for i in 0..10u64 {
            let o = order.clone();
            enqueue_fadd(
                &mut client,
                &mut counter,
                1,
                Completion::new(move |r| {
                    o.borrow_mut().push((i, read_response::<u64>(r)))
                }),
            );
        }
        // 10 records × 32 bytes: fills primary (3 recs) then overflow
        // (7 recs) in one batch.
        client.try_flush(&pair);
        // SAFETY: every record was framed above with matching thunk/env/prop.
        assert_eq!(unsafe { trustee.serve(&pair) }, 10);
        assert_eq!(client.poll(&pair), 10);
        assert_eq!(counter, 10);
        let got = order.borrow().clone();
        // Responses must arrive in submission order: old values 0..9.
        assert_eq!(got, (0..10).map(|i| (i, i)).collect::<Vec<_>>());
    }

    #[test]
    fn serve_filtered_admits_all_or_nothing() {
        fn admit_fadd(thunk_raw: u64) -> bool {
            thunk_raw == (fadd_thunk as Thunk) as usize as u64
        }
        fn admit_none(_: u64) -> bool {
            false
        }

        let pair = SlotPair::default();
        let mut client = ClientEndpoint::default();
        let mut trustee = TrusteeEndpoint::default();
        let mut counter: u64 = 0;

        // Batch 1: a mixed batch (fadd + fire-and-forget add) is rejected
        // by a filter that admits only fadd, then served unconditionally.
        enqueue_fadd(
            &mut client,
            &mut counter,
            1,
            Completion::new(|r| {
                read_response::<u64>(r);
            }),
        );
        client.enqueue_framed(
            add_thunk,
            &mut counter as *mut u64 as *mut u8,
            &2u64.to_le_bytes(),
            Completion::none(),
            |_| {},
        );
        client.try_flush(&pair);
        // SAFETY: same contract as serve — records framed above.
        assert_eq!(unsafe { trustee.serve_filtered(&pair, admit_fadd) }, 0);
        assert_eq!(counter, 0, "rejected batch must apply nothing");
        // SAFETY: every record was framed above with matching thunk/env/prop.
        assert_eq!(unsafe { trustee.serve(&pair) }, 2);
        assert_eq!(counter, 3);
        assert_eq!(client.poll(&pair), 2);

        // Batch 2: a uniform fadd batch passes the filter and is served.
        for _ in 0..3 {
            enqueue_fadd(
                &mut client,
                &mut counter,
                10,
                Completion::new(|r| {
                    read_response::<u64>(r);
                }),
            );
        }
        client.try_flush(&pair);
        // SAFETY: same contract as serve — records framed above.
        assert_eq!(unsafe { trustee.serve_filtered(&pair, admit_none) }, 0);
        // SAFETY: same contract as serve — records framed above.
        assert_eq!(unsafe { trustee.serve_filtered(&pair, admit_fadd) }, 3);
        assert_eq!(counter, 33);
        assert_eq!(client.poll(&pair), 3);
        assert_eq!(client.pending(), 0);
    }

    #[test]
    fn fire_and_forget_no_response_bytes() {
        let pair = SlotPair::default();
        let mut client = ClientEndpoint::default();
        let mut trustee = TrusteeEndpoint::default();
        let mut counter: u64 = 0;

        for _ in 0..3 {
            client.enqueue_framed(
                add_thunk,
                &mut counter as *mut u64 as *mut u8,
                &7u64.to_le_bytes(),
                Completion::none(),
                |_| {},
            );
        }
        client.try_flush(&pair);
        // SAFETY: every record was framed above with matching thunk/env/prop.
        assert_eq!(unsafe { trustee.serve(&pair) }, 3);
        let h = pair.response.header_acquire();
        assert_eq!(h.primary_len(), 0, "no response bytes for fire-and-forget");
        assert_eq!(client.poll(&pair), 3);
        assert_eq!(counter, 21);
    }

    #[test]
    fn serialized_args_and_variable_response() {
        let pair = SlotPair::default();
        let mut client = ClientEndpoint::default();
        let mut trustee = TrusteeEndpoint::default();
        let mut acc: u64 = 0;

        let got = Rc::new(std::cell::RefCell::new(String::new()));
        let g = got.clone();
        // Arguments serialize directly into the outbox arena.
        client.enqueue_framed(
            arg_thunk,
            &mut acc as *mut u64 as *mut u8,
            &[],
            Completion::new(move |r| *g.borrow_mut() = read_response::<String>(r)),
            |w| "hello".to_string().write(w),
        );
        client.try_flush(&pair);
        // SAFETY: every record was framed above with matching thunk/env/prop.
        unsafe { trustee.serve(&pair) };
        client.poll(&pair);
        assert_eq!(&*got.borrow(), "HELLO");
        assert_eq!(acc, 5);
    }

    #[test]
    fn outbox_queues_while_batch_in_flight() {
        let pair = SlotPair::default();
        let mut client = ClientEndpoint::default();
        let mut trustee = TrusteeEndpoint::default();
        let mut counter: u64 = 0;

        enqueue_fadd(
            &mut client,
            &mut counter,
            1,
            Completion::new(|r| {
                read_response::<u64>(r);
            }),
        );
        client.try_flush(&pair);
        // Second request while first is in flight: must queue, not clobber.
        enqueue_fadd(
            &mut client,
            &mut counter,
            2,
            Completion::new(|r| {
                read_response::<u64>(r);
            }),
        );
        assert_eq!(client.try_flush(&pair), 0, "slot busy");
        assert_eq!(client.pending(), 2);

        // SAFETY: every record was framed above with matching thunk/env/prop.
        unsafe { trustee.serve(&pair) };
        // poll dispatches batch 1 AND flushes batch 2.
        assert_eq!(client.poll(&pair), 1);
        // SAFETY: every record was framed above with matching thunk/env/prop.
        unsafe { trustee.serve(&pair) };
        assert_eq!(client.poll(&pair), 1);
        assert_eq!(counter, 3);
        assert_eq!(client.pending(), 0);
    }

    #[test]
    fn huge_args_take_heap_path_and_buffers_recycle() {
        let pair = SlotPair::default();
        let mut client = ClientEndpoint::default();
        let mut trustee = TrusteeEndpoint::default();
        let mut acc: u64 = 0;

        // args larger than the overflow block force FLAG_HEAP.
        ///
        /// # Safety
        /// `prop` points at the test's live `u64`.
        unsafe fn count_thunk(
            _env: *const u8,
            prop: *mut u8,
            args: &[u8],
            out: &mut ResponseWriter,
        ) {
            let mut r = WireReader::new(args);
            let v = Vec::<u8>::read(&mut r).unwrap();
            // SAFETY: prop is the test's live u64 accumulator.
            unsafe { *prop.cast::<u64>() = v.len() as u64 };
            out.write_value(&(v.len() as u64));
        }
        for round in 0..3u64 {
            let got = Rc::new(Cell::new(0u64));
            let g = got.clone();
            let big_args = vec![1u8; 4000];
            client.enqueue_framed(
                count_thunk,
                &mut acc as *mut u64 as *mut u8,
                &[],
                Completion::new(move |r| g.set(read_response::<u64>(r))),
                |w| big_args.write(w),
            );
            client.try_flush(&pair);
            // SAFETY: every record was framed above with matching thunk/env/prop.
            unsafe { trustee.serve(&pair) };
            client.poll(&pair);
            assert_eq!(got.get(), 4000);
            assert_eq!(acc, 4000);
            if round == 0 {
                assert_eq!(client.heap_records, 1);
                assert_eq!(
                    trustee.heap_pool.len(),
                    1,
                    "trustee must bank the client's payload buffer"
                );
            }
        }
        assert_eq!(client.heap_records, 3);
        // Cross-feeding: the banked payload buffers now serve a response
        // spill without a fresh allocation.
        ///
        /// # Safety
        /// Dereferences nothing; `unsafe` only to match the `Thunk` signature.
        unsafe fn big_resp_thunk(
            _env: *const u8,
            _prop: *mut u8,
            _args: &[u8],
            out: &mut ResponseWriter,
        ) {
            out.write_value(&vec![0xCDu8; 5000]);
        }
        client.enqueue_framed(
            big_resp_thunk,
            &mut acc as *mut u64 as *mut u8,
            &[],
            Completion::new(|r| {
                assert_eq!(read_response::<Vec<u8>>(r).len(), 5000);
            }),
            |_| {},
        );
        client.try_flush(&pair);
        // SAFETY: every record was framed above with matching thunk/env/prop.
        unsafe { trustee.serve(&pair) };
        client.poll(&pair);
        assert_eq!(trustee.heap_pool.hits, 1, "spill must reuse a banked buffer");
    }

    #[test]
    fn huge_response_spills_and_spill_buffer_recycles() {
        let pair = SlotPair::default();
        let mut client = ClientEndpoint::default();
        let mut trustee = TrusteeEndpoint::default();
        let mut acc: u64 = 0;

        ///
        /// # Safety
        /// `env` holds a framed `u64` response length.
        unsafe fn big_resp_thunk(
            env: *const u8,
            _prop: *mut u8,
            _args: &[u8],
            out: &mut ResponseWriter,
        ) {
            // SAFETY: env is the framed u64 length.
            let n = unsafe { env.cast::<u64>().read_unaligned() };
            out.write_value(&vec![0xABu8; n as usize]);
        }
        for round in 0..3 {
            let got = Rc::new(Cell::new(0usize));
            let g = got.clone();
            client.enqueue_framed(
                big_resp_thunk,
                &mut acc as *mut u64 as *mut u8,
                &5000u64.to_le_bytes(),
                Completion::new(move |r| {
                    let v = read_response::<Vec<u8>>(r);
                    assert!(v.iter().all(|&b| b == 0xAB));
                    g.set(v.len());
                }),
                |_| {},
            );
            client.try_flush(&pair);
            // SAFETY: every record was framed above with matching thunk/env/prop.
            unsafe { trustee.serve(&pair) };
            client.poll(&pair);
            assert_eq!(got.get(), 5000);
            if round == 0 {
                assert_eq!(
                    client.heap_pool.len(),
                    1,
                    "client must bank the trustee's spill buffer"
                );
            }
        }
        // Cross-feeding: the client's banked spill buffers now carry an
        // out-of-line request payload without a fresh allocation (payload
        // sized below the banked spill buffer's capacity, so the take is
        // a genuine hit under the capacity-honest accounting).
        ///
        /// # Safety
        /// `prop` points at the test's live `u64`.
        unsafe fn len_thunk(_e: *const u8, prop: *mut u8, args: &[u8], _o: &mut ResponseWriter) {
            // SAFETY: prop is the test's live u64 accumulator.
            unsafe { *prop.cast::<u64>() = args.len() as u64 };
        }
        let big = vec![9u8; 3000];
        client.enqueue_framed(
            len_thunk,
            &mut acc as *mut u64 as *mut u8,
            &[],
            Completion::none(),
            |w| w.put_bytes(&big),
        );
        client.try_flush(&pair);
        // SAFETY: every record was framed above with matching thunk/env/prop.
        unsafe { trustee.serve(&pair) };
        client.poll(&pair);
        assert_eq!(acc, 3000);
        assert_eq!(client.heap_pool.hits, 1, "payload must reuse a banked buffer");
    }

    #[test]
    fn opt_bytes_roundtrip_borrows() {
        // write_opt_bytes must be readable both via the borrowing
        // read_opt_bytes and as a plain Option<Vec<u8>> (wire compat in
        // both directions).
        let pair = SlotPair::default();
        let mut pool = HeapPool::default();
        let mut rw = ResponseWriter::new();
        rw.write_opt_bytes(Some(b"hello"));
        rw.write_opt_bytes(None);
        rw.write_value(&Some(b"world".to_vec()));
        let bytes = rw.publish(&pair, true, 3, &mut pool);
        let mut r = WireReader::new(&bytes);
        assert_eq!(read_opt_bytes(&mut r), Some(&b"hello"[..]));
        assert_eq!(read_opt_bytes(&mut r), None);
        // Cross-compat: write_value(Option<Vec<u8>>) decodes borrowed too,
        // and write_opt_bytes decodes as an owned Option<Vec<u8>>.
        assert_eq!(read_opt_bytes(&mut r), Some(&b"world"[..]));
        assert!(r.is_empty());
        let mut r = WireReader::new(&bytes);
        assert_eq!(read_response::<Option<Vec<u8>>>(&mut r), Some(b"hello".to_vec()));
        assert_eq!(read_response::<Option<Vec<u8>>>(&mut r), None);
    }

    #[test]
    fn cross_thread_fetch_and_add() {
        use std::sync::atomic::{AtomicBool, Ordering};
        use std::sync::Arc;

        static COUNTER_ADDR: std::sync::atomic::AtomicUsize =
            std::sync::atomic::AtomicUsize::new(0);

        let matrix = Arc::new(Matrix::new(2));
        let stop = Arc::new(AtomicBool::new(false));
        // Trustee thread (worker 1) owns the counter and serves client 0.
        let m2 = matrix.clone();
        let stop2 = stop.clone();
        let trustee_thread = std::thread::spawn(move || {
            let mut counter: u64 = 0;
            COUNTER_ADDR.store(&mut counter as *mut u64 as usize, Ordering::Release);
            let mut ep = TrusteeEndpoint::default();
            while !stop2.load(Ordering::Acquire) {
                // SAFETY: records on this mesh pair were framed with add_thunk and a
                // live counter pointer published via COUNTER_ADDR.
                unsafe { ep.serve(m2.pair(0, 1)) };
                std::thread::yield_now();
            }
            counter
        });

        let prop = loop {
            let a = COUNTER_ADDR.load(Ordering::Acquire);
            if a != 0 {
                break a as *mut u64;
            }
            std::thread::yield_now();
        };

        let mut client = ClientEndpoint::default();
        let pair = matrix.pair(0, 1);
        let sum = Rc::new(Cell::new(0u64));
        let n = 500u64;
        let mut sent = 0u64;
        while sent < n || client.pending() > 0 {
            if sent < n {
                let s = sum.clone();
                enqueue_fadd(
                    &mut client,
                    prop,
                    1,
                    Completion::new(move |r| {
                        s.set(s.get() + read_response::<u64>(r));
                    }),
                );
                sent += 1;
            }
            client.try_flush(pair);
            client.poll(pair);
        }
        stop.store(true, Ordering::Release);
        let final_count = trustee_thread.join().unwrap();
        assert_eq!(final_count, n);
        // fetch-and-add old values: 0 + 1 + ... + (n-1)
        assert_eq!(sum.get(), n * (n - 1) / 2);
        assert!(client.batches >= 1);
        assert_eq!(client.completed, n);
        assert_eq!(client.completion_heap_spills, 0, "hot path must not box");
    }

    #[test]
    fn record_framing_roundtrip_property() {
        use crate::util::quickcheck::check;
        // Frame then serve records with arbitrary env/args sizes; the
        // summing thunk checks payload integrity end-to-end. The property
        // pointer carries the env length so the thunk can slice the env.
        ///
        /// # Safety
        /// `prop` points at a live `u16` holding the env length; `env` is that
        /// many readable bytes.
        unsafe fn sum_thunk(env: *const u8, prop: *mut u8, args: &[u8], out: &mut ResponseWriter) {
            // SAFETY: prop is the test's u16 env-length cell.
            let env_len = unsafe { *prop.cast::<u16>() } as usize;
            // SAFETY: the framer wrote exactly env_len bytes at env.
            let env_bytes = unsafe { std::slice::from_raw_parts(env, env_len) };
            let s: u64 = env_bytes.iter().map(|&b| b as u64).sum::<u64>()
                + args.iter().map(|&b| b as u64).sum::<u64>();
            out.write_value(&s);
        }
        check::<(Vec<u8>, Vec<u8>)>("record-framing", 60, |(env, args)| {
            if env.len() > 60_000 || args.len() > 60_000 {
                return true;
            }
            let pair = SlotPair::default();
            let mut client = ClientEndpoint::default();
            let mut trustee = TrusteeEndpoint::default();
            let mut env_len_holder: u16 = env.len() as u16;
            let want: u64 = env.iter().map(|&b| b as u64).sum::<u64>()
                + args.iter().map(|&b| b as u64).sum::<u64>();
            let got = Rc::new(Cell::new(u64::MAX));
            let g = got.clone();
            client.enqueue_framed(
                sum_thunk,
                &mut env_len_holder as *mut u16 as *mut u8,
                env,
                Completion::new(move |r| g.set(read_response::<u64>(r))),
                |w| w.put_bytes(args),
            );
            client.try_flush(&pair);
            // SAFETY: every record was framed above with matching thunk/env/prop.
            unsafe { trustee.serve(&pair) };
            client.poll(&pair);
            got.get() == want
        });
    }

    #[test]
    fn dropping_endpoint_with_queued_heap_records_frees_them() {
        // A HEAP record framed but never flushed owns its out-of-line
        // buffer through raw parts in the arena; endpoint Drop must free
        // it (leak-checked under sanitizers / alloc counting).
        let mut client = ClientEndpoint::default();
        let mut acc = 0u64;
        let big = vec![3u8; 5000];
        client.enqueue_framed(
            add_thunk,
            &mut acc as *mut u64 as *mut u8,
            &1u64.to_le_bytes(),
            Completion::none(),
            |w| w.put_bytes(&big),
        );
        assert_eq!(client.heap_records, 1);
        drop(client); // must not leak or double-free
    }

    #[test]
    fn arena_recycles_and_compacts() {
        // Steady-state single-request loopback: after warmup the arena
        // must stop growing (clear-on-drain keeps the same allocation).
        // Fire-and-forget records pair with a thunk that writes no
        // response (the NO_RESPONSE contract).
        fn enqueue_add(ep: &mut ClientEndpoint, prop: *mut u64, delta: u64) {
            ep.enqueue_framed(
                add_thunk,
                prop as *mut u8,
                &delta.to_le_bytes(),
                Completion::none(),
                |_| {},
            );
        }
        let pair = SlotPair::default();
        let mut client = ClientEndpoint::default();
        let mut trustee = TrusteeEndpoint::default();
        let mut counter: u64 = 0;
        for _ in 0..4 {
            enqueue_add(&mut client, &mut counter, 1);
            client.try_flush(&pair);
            // SAFETY: every record was framed above with matching thunk/env/prop.
            unsafe { trustee.serve(&pair) };
            client.poll(&pair);
        }
        let cap = client.arena.capacity();
        assert!(cap > 0);
        for _ in 0..64 {
            enqueue_add(&mut client, &mut counter, 1);
            client.try_flush(&pair);
            // SAFETY: every record was framed above with matching thunk/env/prop.
            unsafe { trustee.serve(&pair) };
            client.poll(&pair);
        }
        assert_eq!(client.arena.capacity(), cap, "drained arena must not grow");
        assert_eq!(counter, 68);
    }
}
