//! FIFO ticket lock.

use super::RawLock;
use crate::util::cache::Backoff;
use std::sync::atomic::{AtomicU64, Ordering};

/// Classic ticket lock: fetch-and-increment a ticket, wait for the grant
/// counter. FIFO-fair, one atomic per acquisition.
#[derive(Default)]
pub struct TicketLock {
    next: AtomicU64,
    serving: AtomicU64,
}

impl RawLock for TicketLock {
    type Token = ();
    const NAME: &'static str = "ticket";

    #[inline]
    fn lock(&self) {
        let my = self.next.fetch_add(1, Ordering::Relaxed);
        let mut backoff = Backoff::new();
        while self.serving.load(Ordering::Acquire) != my {
            backoff.snooze();
        }
    }

    #[inline]
    fn try_lock(&self) -> Option<()> {
        let serving = self.serving.load(Ordering::Acquire);
        // Only take a ticket if we'd be served immediately.
        if self
            .next
            .compare_exchange(serving, serving + 1, Ordering::Acquire, Ordering::Relaxed)
            .is_ok()
        {
            Some(())
        } else {
            None
        }
    }

    #[inline]
    fn unlock(&self, _t: ()) {
        self.serving.fetch_add(1, Ordering::Release);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::locks::tests::{exercise_lock, exercise_mutual_exclusion};

    #[test]
    fn ticket_counter_exact() {
        exercise_lock::<TicketLock>();
    }

    #[test]
    fn ticket_mutual_exclusion() {
        exercise_mutual_exclusion::<TicketLock>();
    }

    #[test]
    fn ticket_try_lock() {
        let l = TicketLock::default();
        let t = l.try_lock().unwrap();
        assert!(l.try_lock().is_none());
        l.unlock(t);
        assert!(l.try_lock().is_some());
    }
}
