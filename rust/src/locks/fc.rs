//! Flat-combining lock — the combining-class baseline (Hendler et al.;
//! DESIGN.md substitution #4 for TCLocks).
//!
//! Threads publish their critical sections as records on a lock-free
//! publication stack; whichever thread holds the combiner lock applies
//! *all* published operations before releasing. Like TCLocks, the critical
//! section is "shipped" to another core, and like the paper observes (§2),
//! the technique "makes heavy use of atomic operations, and moves data
//! between cores as new threads take on the combiner role" — which is
//! exactly the overhead profile Fig. 6a shows.

use crate::util::cache::{Backoff, CachePadded};
use std::cell::UnsafeCell;
use std::ptr;
use std::sync::atomic::{AtomicBool, AtomicPtr, Ordering};

/// One published operation. Lives on the requesting thread's stack; the
/// requester spins on `done` and the combiner never touches the record
/// after the Release store to `done`.
struct FcRecord {
    next: *mut FcRecord,
    /// Type-erased critical section: `call(ctx)` applies the closure to
    /// the value and stores the result in the requester's stack frame.
    call: unsafe fn(ctx: *mut u8, value: *mut u8),
    ctx: *mut u8,
    done: AtomicBool,
}

/// A flat-combining protected value.
pub struct FcLock<T> {
    combiner: CachePadded<AtomicBool>,
    head: CachePadded<AtomicPtr<FcRecord>>,
    value: UnsafeCell<T>,
}

// SAFETY: `value` is only touched by the combiner, which is unique.
unsafe impl<T: Send> Send for FcLock<T> {}
// SAFETY: sharing is safe because every access to `value` funnels
// through the unique combiner — no concurrent &mut T can exist.
unsafe impl<T: Send> Sync for FcLock<T> {}

impl<T> FcLock<T> {
    pub fn new(value: T) -> FcLock<T> {
        FcLock {
            combiner: CachePadded::new(AtomicBool::new(false)),
            head: CachePadded::new(AtomicPtr::new(ptr::null_mut())),
            value: UnsafeCell::new(value),
        }
    }

    pub fn into_inner(self) -> T {
        self.value.into_inner()
    }

    /// Apply `f` to the protected value, possibly by combining it into
    /// another thread's pass.
    pub fn apply<R, F: FnOnce(&mut T) -> R>(&self, f: F) -> R {
        // Stack context: closure in, result out.
        struct Ctx<T, R, F> {
            f: Option<F>,
            result: Option<R>,
            _marker: std::marker::PhantomData<fn(&mut T)>,
        }
        // SAFETY: caller passes ctx pointing at a live Ctx<T, R, F> and value
        // at the lock's T; invoked once per record by the combiner.
        unsafe fn call_one<T, R, F: FnOnce(&mut T) -> R>(ctx: *mut u8, value: *mut u8) {
            // SAFETY: ctx/value types match by construction below.
            unsafe {
                let ctx = &mut *(ctx as *mut Ctx<T, R, F>);
                let f = ctx.f.take().expect("op applied twice");
                ctx.result = Some(f(&mut *(value as *mut T)));
            }
        }

        let mut ctx = Ctx::<T, R, F> { f: Some(f), result: None, _marker: std::marker::PhantomData };
        let mut rec = FcRecord {
            next: ptr::null_mut(),
            call: call_one::<T, R, F>,
            ctx: &mut ctx as *mut Ctx<T, R, F> as *mut u8,
            done: AtomicBool::new(false),
        };

        // Publish.
        let rec_ptr = &mut rec as *mut FcRecord;
        let mut head = self.head.load(Ordering::Relaxed);
        loop {
            rec.next = head;
            match self.head.compare_exchange_weak(
                head,
                rec_ptr,
                Ordering::Release,
                Ordering::Relaxed,
            ) {
                Ok(_) => break,
                Err(h) => head = h,
            }
        }

        // Wait-or-combine.
        let mut backoff = Backoff::new();
        loop {
            if rec.done.load(Ordering::Acquire) {
                return ctx.result.take().expect("combined without result");
            }
            if self
                .combiner
                .compare_exchange(false, true, Ordering::Acquire, Ordering::Relaxed)
                .is_ok()
            {
                self.combine();
                self.combiner.store(false, Ordering::Release);
                if rec.done.load(Ordering::Acquire) {
                    return ctx.result.take().expect("combined without result");
                }
            }
            backoff.snooze();
        }
    }

    /// Drain the publication stack and apply everything (combiner role).
    fn combine(&self) {
        // Take the whole list; new arrivals republish onto an empty head.
        let mut cur = self.head.swap(ptr::null_mut(), Ordering::AcqRel);
        while !cur.is_null() {
            // SAFETY: records are live until we set `done`; read `next`
            // first because the record may be reclaimed right after.
            unsafe {
                let next = (*cur).next;
                ((*cur).call)((*cur).ctx, self.value.get() as *mut u8);
                (*cur).done.store(true, Ordering::Release);
                cur = next;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    #[test]
    fn single_thread_apply() {
        let l = FcLock::new(10u64);
        let old = l.apply(|v| {
            let o = *v;
            *v += 5;
            o
        });
        assert_eq!(old, 10);
        assert_eq!(l.apply(|v| *v), 15);
    }

    #[test]
    fn multi_thread_counter_exact() {
        let l = Arc::new(FcLock::new(0u64));
        let threads = 4;
        let iters = 2_000u64;
        let handles: Vec<_> = (0..threads)
            .map(|_| {
                let l = l.clone();
                std::thread::spawn(move || {
                    for _ in 0..iters {
                        l.apply(|v| *v += 1);
                    }
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(l.apply(|v| *v), threads as u64 * iters);
    }

    #[test]
    fn returns_values_to_correct_thread() {
        let l = Arc::new(FcLock::new(0u64));
        let handles: Vec<_> = (0..4)
            .map(|t| {
                let l = l.clone();
                std::thread::spawn(move || {
                    let mut olds = Vec::new();
                    for _ in 0..500 {
                        olds.push(l.apply(|v| {
                            let o = *v;
                            *v += 1;
                            o
                        }));
                    }
                    // Each thread must see strictly increasing old values.
                    assert!(olds.windows(2).all(|w| w[0] < w[1]), "thread {t}");
                    olds.len()
                })
            })
            .collect();
        let total: usize = handles.into_iter().map(|h| h.join().unwrap()).sum();
        assert_eq!(total, 2000);
        assert_eq!(l.apply(|v| *v), 2000);
    }

    #[test]
    fn mixed_types_in_critical_sections() {
        let l = Arc::new(FcLock::new(String::new()));
        let l2 = l.clone();
        let t = std::thread::spawn(move || {
            for _ in 0..100 {
                l2.apply(|s| s.push('b'));
            }
        });
        for _ in 0..100 {
            l.apply(|s| s.push('a'));
        }
        t.join().unwrap();
        let len = l.apply(|s| s.len());
        assert_eq!(len, 200);
    }
}
