//! Lock baselines the paper evaluates Trust\<T\> against (§6):
//!
//! - [`SpinLock`] — test-and-test-and-set (the `spin-rs` crate stand-in)
//! - [`TicketLock`] — FIFO ticket lock
//! - [`McsLock`] — queue lock (the `synctools` MCS stand-in)
//! - [`FcLock`] — flat combining (the TCLocks / combining-class stand-in,
//!   DESIGN.md substitution #4)
//! - `std::sync::Mutex` — used directly by the benches as "Mutex"
//!
//! All locks share the [`RawLock`] interface so the fetch-and-add
//! microbenchmark (Fig. 6/7) is generic over the lock type, and the
//! [`LockCell`] combinator pairs a lock with a value, mirroring
//! `Mutex<T>`.
//!
//! **Single-core substitution:** every spin path escalates to OS yields via
//! [`Backoff`](crate::util::cache::Backoff) — on the paper's 128-thread
//! testbed spinning burns a hardware thread, but on this container it would
//! starve the lock holder outright (DESIGN.md substitution #1).

mod fc;
mod mcs;
mod spin;
mod ticket;

pub use fc::FcLock;
pub use mcs::McsLock;
pub use spin::SpinLock;
pub use ticket::TicketLock;

use std::cell::UnsafeCell;

/// A raw mutual-exclusion primitive. `Token` carries queue-node state for
/// locks that need it (MCS); plain locks use `()`.
pub trait RawLock: Send + Sync + Default {
    type Token;
    const NAME: &'static str;

    fn lock(&self) -> Self::Token;
    fn try_lock(&self) -> Option<Self::Token>;
    fn unlock(&self, token: Self::Token);
}

/// A lock paired with the value it protects (like `Mutex<T>` but generic
/// over [`RawLock`]).
pub struct LockCell<L: RawLock, T> {
    lock: L,
    value: UnsafeCell<T>,
}

// SAFETY: access to `value` is serialized by `lock`.
unsafe impl<L: RawLock, T: Send> Send for LockCell<L, T> {}
// SAFETY: as for Send — the raw lock serializes every &mut T that
// with_lock hands out.
unsafe impl<L: RawLock, T: Send> Sync for LockCell<L, T> {}

impl<L: RawLock, T> LockCell<L, T> {
    pub fn new(value: T) -> Self {
        LockCell { lock: L::default(), value: UnsafeCell::new(value) }
    }

    /// Run `f` under the lock.
    #[inline]
    pub fn with_lock<R>(&self, f: impl FnOnce(&mut T) -> R) -> R {
        let tok = self.lock.lock();
        // SAFETY: lock held.
        let r = f(unsafe { &mut *self.value.get() });
        self.lock.unlock(tok);
        r
    }

    /// Run `f` under the lock if it is immediately available.
    #[inline]
    pub fn try_with_lock<R>(&self, f: impl FnOnce(&mut T) -> R) -> Option<R> {
        let tok = self.lock.try_lock()?;
        // SAFETY: lock held.
        let r = f(unsafe { &mut *self.value.get() });
        self.lock.unlock(tok);
        Some(r)
    }

    pub fn into_inner(self) -> T {
        self.value.into_inner()
    }
}

#[cfg(test)]
pub(crate) mod tests {
    use super::*;
    use std::sync::Arc;

    /// Hammer a counter from several threads; the total must be exact.
    pub(crate) fn exercise_lock<L: RawLock + 'static>() {
        let cell = Arc::new(LockCell::<L, u64>::new(0));
        let threads = 4;
        let iters = 2_000u64;
        let handles: Vec<_> = (0..threads)
            .map(|_| {
                let cell = cell.clone();
                std::thread::spawn(move || {
                    for _ in 0..iters {
                        cell.with_lock(|v| *v += 1);
                    }
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(cell.with_lock(|v| *v), threads as u64 * iters);
    }

    /// Critical sections must be mutually exclusive (flag check).
    pub(crate) fn exercise_mutual_exclusion<L: RawLock + 'static>() {
        let cell = Arc::new(LockCell::<L, (bool, u64)>::new((false, 0)));
        let handles: Vec<_> = (0..3)
            .map(|_| {
                let cell = cell.clone();
                std::thread::spawn(move || {
                    for _ in 0..500 {
                        cell.with_lock(|(busy, viol)| {
                            if *busy {
                                *viol += 1;
                            }
                            *busy = true;
                            std::hint::spin_loop();
                            *busy = false;
                        });
                    }
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(cell.with_lock(|(_, viol)| *viol), 0);
    }

    #[test]
    fn try_lock_contract() {
        let cell = LockCell::<SpinLock, u64>::new(5);
        let tok = cell.lock.lock();
        assert!(cell.lock.try_lock().is_none());
        cell.lock.unlock(tok);
        assert!(cell.try_with_lock(|v| *v).is_some());
    }
}
