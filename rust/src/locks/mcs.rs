//! MCS queue lock (the paper's `synctools 0.3.2` MCSLock baseline; §6.1
//! calls MCS "known for their scalability" and measures ≈2.5 MOPs/lock).
//!
//! Each waiter spins on its *own* queue node, so a contended MCS lock
//! generates O(1) coherence traffic per handoff instead of a thundering
//! herd. Queue nodes are pooled per-thread to keep acquisition
//! allocation-free after warm-up.

use super::RawLock;
use crate::util::cache::{Backoff, CachePadded};
use std::cell::RefCell;
use std::ptr;
use std::sync::atomic::{AtomicBool, AtomicPtr, Ordering};

pub struct McsNode {
    next: AtomicPtr<McsNode>,
    locked: AtomicBool,
}

thread_local! {
    /// Per-thread node pool (nodes are only reused after release).
    static NODE_POOL: RefCell<Vec<Box<McsNode>>> = const { RefCell::new(Vec::new()) };
}

fn take_node() -> Box<McsNode> {
    NODE_POOL
        .with(|p| p.borrow_mut().pop())
        .unwrap_or_else(|| {
            Box::new(McsNode {
                next: AtomicPtr::new(ptr::null_mut()),
                locked: AtomicBool::new(false),
            })
        })
}

fn put_node(node: Box<McsNode>) {
    NODE_POOL.with(|p| {
        let mut pool = p.borrow_mut();
        if pool.len() < 8 {
            pool.push(node);
        }
    });
}

/// MCS queue lock.
#[derive(Default)]
pub struct McsLock {
    tail: CachePadded<AtomicPtr<McsNode>>,
}

impl RawLock for McsLock {
    type Token = Box<McsNode>;
    const NAME: &'static str = "mcs";

    fn lock(&self) -> Box<McsNode> {
        let node = take_node();
        node.next.store(ptr::null_mut(), Ordering::Relaxed);
        node.locked.store(true, Ordering::Relaxed);
        let node_ptr = &*node as *const McsNode as *mut McsNode;
        let prev = self.tail.swap(node_ptr, Ordering::AcqRel);
        if !prev.is_null() {
            // SAFETY: prev is a live node — its owner is spinning on
            // `locked` and cannot free it until we set `next`.
            unsafe { (*prev).next.store(node_ptr, Ordering::Release) };
            let mut backoff = Backoff::new();
            while node.locked.load(Ordering::Acquire) {
                backoff.snooze();
            }
        }
        node
    }

    fn try_lock(&self) -> Option<Box<McsNode>> {
        let node = take_node();
        node.next.store(ptr::null_mut(), Ordering::Relaxed);
        node.locked.store(true, Ordering::Relaxed);
        let node_ptr = &*node as *const McsNode as *mut McsNode;
        if self
            .tail
            .compare_exchange(ptr::null_mut(), node_ptr, Ordering::AcqRel, Ordering::Relaxed)
            .is_ok()
        {
            Some(node)
        } else {
            put_node(node);
            None
        }
    }

    fn unlock(&self, node: Box<McsNode>) {
        let node_ptr = &*node as *const McsNode as *mut McsNode;
        let mut next = node.next.load(Ordering::Acquire);
        if next.is_null() {
            // No known successor: try to swing tail back to null.
            if self
                .tail
                .compare_exchange(node_ptr, ptr::null_mut(), Ordering::AcqRel, Ordering::Relaxed)
                .is_ok()
            {
                put_node(node);
                return;
            }
            // A successor is mid-enqueue; wait for it to link itself.
            let mut backoff = Backoff::new();
            loop {
                next = node.next.load(Ordering::Acquire);
                if !next.is_null() {
                    break;
                }
                backoff.snooze();
            }
        }
        // SAFETY: successor is alive and spinning on its `locked` flag.
        unsafe { (*next).locked.store(false, Ordering::Release) };
        put_node(node);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::locks::tests::{exercise_lock, exercise_mutual_exclusion};

    #[test]
    fn mcs_counter_exact() {
        exercise_lock::<McsLock>();
    }

    #[test]
    fn mcs_mutual_exclusion() {
        exercise_mutual_exclusion::<McsLock>();
    }

    #[test]
    fn mcs_try_lock() {
        let l = McsLock::default();
        let t = l.try_lock().unwrap();
        assert!(l.try_lock().is_none());
        l.unlock(t);
        let t2 = l.try_lock().unwrap();
        l.unlock(t2);
    }

    #[test]
    fn mcs_handoff_chain() {
        // Serial lock/unlock from several threads exercises the
        // tail-swing and successor-wait paths.
        let l = std::sync::Arc::new(McsLock::default());
        let mut handles = Vec::new();
        for _ in 0..4 {
            let l = l.clone();
            handles.push(std::thread::spawn(move || {
                for _ in 0..500 {
                    let t = l.lock();
                    l.unlock(t);
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
    }
}
