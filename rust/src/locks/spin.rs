//! Test-and-test-and-set spinlock (the paper's `spin-rs 0.9.8` baseline).

use super::RawLock;
use crate::util::cache::Backoff;
use std::sync::atomic::{AtomicBool, Ordering};

/// TTAS spinlock with exponential backoff + OS-yield escalation.
#[derive(Default)]
pub struct SpinLock {
    locked: AtomicBool,
}

impl RawLock for SpinLock {
    type Token = ();
    const NAME: &'static str = "spinlock";

    #[inline]
    fn lock(&self) {
        let mut backoff = Backoff::new();
        loop {
            // Test-and-test-and-set: spin on a plain load first so the
            // cache line stays shared until it looks free.
            if !self.locked.load(Ordering::Relaxed)
                && self
                    .locked
                    .compare_exchange_weak(false, true, Ordering::Acquire, Ordering::Relaxed)
                    .is_ok()
            {
                return;
            }
            backoff.snooze();
        }
    }

    #[inline]
    fn try_lock(&self) -> Option<()> {
        if !self.locked.load(Ordering::Relaxed)
            && self
                .locked
                .compare_exchange(false, true, Ordering::Acquire, Ordering::Relaxed)
                .is_ok()
        {
            Some(())
        } else {
            None
        }
    }

    #[inline]
    fn unlock(&self, _t: ()) {
        self.locked.store(false, Ordering::Release);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::locks::tests::{exercise_lock, exercise_mutual_exclusion};

    #[test]
    fn spin_counter_exact() {
        exercise_lock::<SpinLock>();
    }

    #[test]
    fn spin_mutual_exclusion() {
        exercise_mutual_exclusion::<SpinLock>();
    }

    #[test]
    fn lock_unlock_single_thread() {
        let l = SpinLock::default();
        let t = l.lock();
        l.unlock(t);
        let t = l.try_lock().unwrap();
        l.unlock(t);
    }
}
