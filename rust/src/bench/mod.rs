//! Benchmark harness library: the fetch-and-add microbenchmark engines
//! behind Figures 6 and 7, shared by the `rust/benches/*` figure drivers.
//!
//! §6.1's setup: "a number of threads repeatedly increment a counter chosen
//! from a set of one or more, and fetches the value of the counter ... we
//! also include a single `pause` instruction in both the critical section
//! and the delegated closures. The counter is chosen at random, either from
//! a uniform distribution, or a zipfian distribution."

pub mod fadd;
pub mod latency;

pub use fadd::{FaddConfig, FaddResult};
pub use latency::{LatencyConfig, LatencyResult};

/// Print a CSV header + rows helper used by all figure drivers.
pub fn print_table(title: &str, header: &[&str], rows: &[Vec<String>]) {
    println!("\n# {title}");
    println!("{}", header.join(","));
    for row in rows {
        println!("{}", row.join(","));
    }
}
