//! Fetch-and-add throughput engines (Figure 6a/6b) for every contender:
//! std Mutex, spinlock, ticket, MCS, flat-combining (TCLocks stand-in),
//! Trust (blocking fibers) and Async (non-blocking delegation).

use crate::channel::FlushPolicy;
use crate::locks::{FcLock, LockCell, McsLock, RawLock, SpinLock, TicketLock};
use crate::runtime::Runtime;
use crate::trust::Trust;
use crate::util::cache::{pause, CachePadded};
use crate::util::{KeyDist, Rng};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Barrier, Mutex};
use std::time::Instant;

/// Configuration for one fetch-and-add run.
#[derive(Clone, Debug)]
pub struct FaddConfig {
    /// Client threads (lock benches) / client workers (delegation).
    pub threads: usize,
    /// Number of counters.
    pub objects: usize,
    /// Increments per thread.
    pub ops_per_thread: u64,
    /// "uniform" or "zipf[:alpha]".
    pub dist: String,
    pub seed: u64,
    /// Trust-specific: dedicated trustee workers (0 = shared mode, every
    /// worker is both client and trustee, §6.1's *shared*).
    pub dedicated: usize,
    /// Trust-specific: concurrent fibers per client worker.
    pub fibers: usize,
    /// Async-specific: outstanding requests per client worker.
    pub window: usize,
    /// Trust-specific: client-side flush policy (adaptive batching vs the
    /// pre-refactor eager per-request flush) — the channel_micro
    /// batched-vs-eager scenario sweeps this.
    pub flush: FlushPolicy,
}

impl Default for FaddConfig {
    fn default() -> Self {
        FaddConfig {
            threads: 8,
            objects: 64,
            ops_per_thread: 20_000,
            dist: "uniform".into(),
            seed: 0xFADD,
            dedicated: 0,
            fibers: 16,
            window: 64,
            flush: FlushPolicy::Adaptive,
        }
    }
}

/// Result of one run.
#[derive(Clone, Copy, Debug)]
pub struct FaddResult {
    pub ops: u64,
    pub secs: f64,
}

impl FaddResult {
    pub fn mops(&self) -> f64 {
        self.ops as f64 / self.secs / 1e6
    }
}

/// The checksum every engine must reproduce: each counter ends at its
/// access count; total increments == threads * ops_per_thread.
fn check_total(counts: &[u64], cfg: &FaddConfig) {
    let total: u64 = counts.iter().sum();
    assert_eq!(
        total,
        cfg.threads as u64 * cfg.ops_per_thread,
        "lost updates detected"
    );
}

// ---------------------------------------------------------------------
// Lock engines
// ---------------------------------------------------------------------

/// Generic engine over [`RawLock`].
pub fn run_rawlock<L: RawLock + 'static>(cfg: &FaddConfig) -> FaddResult {
    let objects: Arc<Vec<CachePadded<LockCell<L, u64>>>> = Arc::new(
        (0..cfg.objects)
            .map(|_| CachePadded::new(LockCell::new(0)))
            .collect(),
    );
    run_lock_threads(cfg, objects.clone(), move |objects, obj| {
        objects[obj].with_lock(|c| {
            pause(); // the paper's in-critical-section pause
            *c += 1;
            *c // fetch
        });
    })
}

/// std::sync::Mutex engine (the paper's "Mutex").
pub fn run_std_mutex(cfg: &FaddConfig) -> FaddResult {
    let objects: Arc<Vec<CachePadded<Mutex<u64>>>> = Arc::new(
        (0..cfg.objects)
            .map(|_| CachePadded::new(Mutex::new(0)))
            .collect(),
    );
    run_lock_threads(cfg, objects.clone(), move |objects, obj| {
        let mut c = objects[obj].lock().unwrap();
        pause();
        *c += 1;
        let _ = *c;
    })
}

/// Flat-combining engine (TCLocks stand-in).
pub fn run_flat_combining(cfg: &FaddConfig) -> FaddResult {
    let objects: Arc<Vec<FcLock<u64>>> =
        Arc::new((0..cfg.objects).map(|_| FcLock::new(0)).collect());
    run_lock_threads(cfg, objects.clone(), move |objects, obj| {
        objects[obj].apply(|c| {
            pause();
            *c += 1;
            *c
        });
    })
}

fn run_lock_threads<O: Send + Sync + 'static>(
    cfg: &FaddConfig,
    objects: Arc<O>,
    op: impl Fn(&O, usize) + Send + Sync + Copy + 'static,
) -> FaddResult {
    let barrier = Arc::new(Barrier::new(cfg.threads + 1));
    let handles: Vec<_> = (0..cfg.threads)
        .map(|t| {
            let objects = objects.clone();
            let barrier = barrier.clone();
            let cfg = cfg.clone();
            std::thread::spawn(move || {
                let mut rng = Rng::new(cfg.seed ^ (t as u64) << 17);
                let dist = KeyDist::from_spec(&cfg.dist, cfg.objects as u64);
                barrier.wait();
                for _ in 0..cfg.ops_per_thread {
                    let obj = dist.sample(&mut rng) as usize;
                    op(&objects, obj);
                }
            })
        })
        .collect();
    // Take the clock BEFORE releasing the barrier: on a single-CPU box the
    // worker threads can run to completion before this thread is scheduled
    // again, which would make an after-the-barrier timestamp miss the
    // entire run.
    let start = Instant::now();
    barrier.wait();
    for h in handles {
        h.join().expect("bench thread");
    }
    let secs = start.elapsed().as_secs_f64();
    FaddResult { ops: cfg.threads as u64 * cfg.ops_per_thread, secs }
}

/// Convenience dispatch by name (bench CLI).
pub fn run_lock_by_name(name: &str, cfg: &FaddConfig) -> FaddResult {
    match name {
        "mutex" => run_std_mutex(cfg),
        "spin" => run_rawlock::<SpinLock>(cfg),
        "ticket" => run_rawlock::<TicketLock>(cfg),
        "mcs" => run_rawlock::<McsLock>(cfg),
        "fc" | "tclocks" => run_flat_combining(cfg),
        other => panic!("unknown lock engine {other:?}"),
    }
}

// ---------------------------------------------------------------------
// Delegation engines
// ---------------------------------------------------------------------

/// Build the runtime + entrusted counters for a delegation run.
/// Counters are spread round-robin over trustees (dedicated workers if
/// `cfg.dedicated > 0`, else all workers).
fn setup_trust(cfg: &FaddConfig) -> (Runtime, Vec<Trust<u64>>, Vec<usize>) {
    let workers = cfg.dedicated + cfg.threads;
    let rt = Runtime::builder()
        .workers(workers)
        .dedicated_trustees(cfg.dedicated)
        .flush_policy(cfg.flush)
        .build();
    let trustee_ids: Vec<usize> = if cfg.dedicated > 0 {
        (0..cfg.dedicated).collect()
    } else {
        (0..workers).collect()
    };
    let mut counters = Vec::with_capacity(cfg.objects);
    for o in 0..cfg.objects {
        let w = trustee_ids[o % trustee_ids.len()];
        counters.push(rt.trustee(w).entrust(0u64));
    }
    let clients: Vec<usize> = (cfg.dedicated..workers).collect();
    (rt, counters, clients)
}

/// Blocking delegation ("Trust" series): `fibers` synchronous fibers per
/// client worker, each issuing `apply` and suspending.
pub fn run_trust(cfg: &FaddConfig) -> FaddResult {
    let (rt, counters, clients) = setup_trust(cfg);
    let counters = Arc::new(counters);
    let done = Arc::new(AtomicU64::new(0));
    let total_fibers = clients.len() * cfg.fibers;
    let ops_per_fiber = cfg.ops_per_thread * cfg.threads as u64 / total_fibers as u64;

    let start = Instant::now();
    for (ci, &w) in clients.iter().enumerate() {
        for f in 0..cfg.fibers {
            let counters = counters.clone();
            let done = done.clone();
            let cfg2 = cfg.clone();
            let seed = cfg.seed ^ ((ci * cfg.fibers + f) as u64) << 13;
            rt.spawn_on(w, move || {
                let mut rng = Rng::new(seed);
                let dist = KeyDist::from_spec(&cfg2.dist, cfg2.objects as u64);
                for _ in 0..ops_per_fiber {
                    let obj = dist.sample(&mut rng) as usize;
                    counters[obj].apply(|c| {
                        pause(); // delegated-closure pause (§6.1)
                        *c += 1;
                        *c
                    });
                }
                done.fetch_add(1, Ordering::AcqRel);
            });
        }
    }
    while done.load(Ordering::Acquire) != total_fibers as u64 {
        std::thread::yield_now();
    }
    let secs = start.elapsed().as_secs_f64();
    let ops = ops_per_fiber * total_fibers as u64;

    // Verify: sum of counters equals ops issued.
    let counts: Vec<u64> = {
        let counters = counters.clone();
        let n = counters.len();
        rt.block_on(clients[0], move || {
            (0..n).map(|i| counters[i].apply(|c| *c)).collect()
        })
    };
    assert_eq!(counts.iter().sum::<u64>(), ops, "lost updates");
    drop(counters);
    rt.shutdown();
    FaddResult { ops, secs }
}

/// Non-blocking delegation ("Async" series): one fiber per client worker
/// keeps `window` apply_then requests outstanding.
pub fn run_async(cfg: &FaddConfig) -> FaddResult {
    let (rt, counters, clients) = setup_trust(cfg);
    let counters = Arc::new(counters);
    let done = Arc::new(AtomicU64::new(0));
    let ops_per_client = cfg.ops_per_thread * cfg.threads as u64 / clients.len() as u64;

    let start = Instant::now();
    for (ci, &w) in clients.iter().enumerate() {
        let counters = counters.clone();
        let done = done.clone();
        let cfg2 = cfg.clone();
        let seed = cfg.seed ^ (ci as u64) << 11;
        rt.spawn_on(w, move || {
            use std::cell::Cell;
            use std::rc::Rc;
            let mut rng = Rng::new(seed);
            let dist = KeyDist::from_spec(&cfg2.dist, cfg2.objects as u64);
            let completed = Rc::new(Cell::new(0u64));
            // Park the issuing fiber while the window is full; the first
            // completion of each response batch resumes it. Busy-yielding
            // here would starve the trustee thread of CPU on small boxes.
            let parked: Rc<Cell<Option<crate::fiber::FiberId>>> = Rc::new(Cell::new(None));
            let mut issued = 0u64;
            while completed.get() < ops_per_client {
                while issued < ops_per_client
                    && issued - completed.get() < cfg2.window as u64
                {
                    let obj = dist.sample(&mut rng) as usize;
                    let comp = completed.clone();
                    let parked2 = parked.clone();
                    counters[obj].apply_then(
                        |c| {
                            pause();
                            *c += 1;
                            *c
                        },
                        move |_v| {
                            comp.set(comp.get() + 1);
                            if let Some(id) = parked2.take() {
                                crate::fiber::with_executor(|e| e.resume(id));
                            }
                        },
                    );
                    issued += 1;
                }
                if completed.get() < ops_per_client {
                    crate::fiber::suspend(|id| parked.set(Some(id)));
                }
            }
            done.fetch_add(1, Ordering::AcqRel);
        });
    }
    while done.load(Ordering::Acquire) != clients.len() as u64 {
        std::thread::yield_now();
    }
    let secs = start.elapsed().as_secs_f64();
    let ops = ops_per_client * clients.len() as u64;

    let counts: Vec<u64> = {
        let counters = counters.clone();
        let n = counters.len();
        rt.block_on(clients[0], move || {
            (0..n).map(|i| counters[i].apply(|c| *c)).collect()
        })
    };
    assert_eq!(counts.iter().sum::<u64>(), ops, "lost updates");
    drop(counters);
    rt.shutdown();
    FaddResult { ops, secs }
}

#[allow(unused)]
fn unused_check(counts: &[u64], cfg: &FaddConfig) {
    check_total(counts, cfg)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn quick_cfg(objects: usize) -> FaddConfig {
        FaddConfig {
            threads: 2,
            objects,
            ops_per_thread: 500,
            fibers: 2,
            window: 16,
            ..Default::default()
        }
    }

    #[test]
    fn all_lock_engines_count_exactly() {
        for name in ["mutex", "spin", "ticket", "mcs", "fc"] {
            let r = run_lock_by_name(name, &quick_cfg(8));
            assert_eq!(r.ops, 1000, "{name}");
            assert!(r.secs > 0.0);
        }
    }

    #[test]
    fn trust_engine_counts_exactly() {
        let r = run_trust(&quick_cfg(4));
        assert_eq!(r.ops, 1000);
    }

    #[test]
    fn async_engine_counts_exactly() {
        let r = run_async(&quick_cfg(4));
        assert_eq!(r.ops, 1000);
    }

    #[test]
    fn dedicated_trustees_work() {
        let mut cfg = quick_cfg(4);
        cfg.dedicated = 1;
        let r = run_trust(&cfg);
        assert_eq!(r.ops, 1000);
        let r = run_async(&cfg);
        assert_eq!(r.ops, 1000);
    }

    #[test]
    fn zipf_dist_works_across_engines() {
        let mut cfg = quick_cfg(16);
        cfg.dist = "zipf".into();
        assert_eq!(run_std_mutex(&cfg).ops, 1000);
        assert_eq!(run_trust(&cfg).ops, 1000);
    }

    #[test]
    fn single_object_contended() {
        let cfg = quick_cfg(1);
        assert_eq!(run_std_mutex(&cfg).ops, 1000);
        assert_eq!(run_trust(&cfg).ops, 1000);
        assert_eq!(run_async(&cfg).ops, 1000);
    }
}
