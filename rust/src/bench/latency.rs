//! Open-loop latency-vs-offered-load engines (Figure 7a/7b).
//!
//! §6.2: "we measure mean latency ... while varying the offered load."
//! Open-loop accounting: each operation has a scheduled arrival time drawn
//! from the offered rate; latency = completion − scheduled arrival, so
//! queueing delay counts when the system falls behind (this is what makes
//! the near-vertical "capacity" walls visible).

use super::fadd::FaddConfig;
use crate::locks::{LockCell, McsLock, SpinLock};
use crate::trust::Trust;
use crate::util::cache::{pause, CachePadded};
use crate::util::stats::LatencyHist;
use crate::util::{KeyDist, Rng};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Barrier, Mutex};
use std::time::{Duration, Instant};

#[derive(Clone, Debug)]
pub struct LatencyConfig {
    pub threads: usize,
    pub objects: usize,
    /// Total offered load, operations per second (spread over threads).
    pub offered_ops_per_sec: f64,
    /// Ops per thread for the run.
    pub ops_per_thread: u64,
    pub dist: String,
    pub seed: u64,
    pub dedicated: usize,
}

#[derive(Clone)]
pub struct LatencyResult {
    pub hist: LatencyHist,
    pub achieved_ops_per_sec: f64,
}

impl LatencyResult {
    pub fn mean_us(&self) -> f64 {
        self.hist.mean() / 1000.0
    }

    pub fn p999_us(&self) -> f64 {
        self.hist.quantile(0.999) as f64 / 1000.0
    }
}

/// Lock-based open-loop run, generic over the protected op.
fn run_lock_open_loop<O: Send + Sync + 'static>(
    cfg: &LatencyConfig,
    objects: Arc<O>,
    op: impl Fn(&O, usize) + Send + Sync + Copy + 'static,
) -> LatencyResult {
    let per_thread_rate = cfg.offered_ops_per_sec / cfg.threads as f64;
    let interval = Duration::from_secs_f64(1.0 / per_thread_rate);
    let barrier = Arc::new(Barrier::new(cfg.threads + 1));
    let handles: Vec<_> = (0..cfg.threads)
        .map(|t| {
            let objects = objects.clone();
            let barrier = barrier.clone();
            let cfg = cfg.clone();
            std::thread::spawn(move || {
                let mut rng = Rng::new(cfg.seed ^ (t as u64) << 9);
                let dist = KeyDist::from_spec(&cfg.dist, cfg.objects as u64);
                let mut hist = LatencyHist::new();
                barrier.wait();
                let start = Instant::now();
                for i in 0..cfg.ops_per_thread {
                    let scheduled = start + interval.mul_f64(i as f64);
                    let now = Instant::now();
                    if now < scheduled {
                        // Open loop: wait for the arrival.
                        std::thread::sleep(scheduled - now);
                    }
                    let obj = dist.sample(&mut rng) as usize;
                    op(&objects, obj);
                    hist.record(scheduled.elapsed().as_nanos() as u64);
                }
                hist
            })
        })
        .collect();
    barrier.wait();
    let start = Instant::now();
    let mut hist = LatencyHist::new();
    for h in handles {
        hist.merge(&h.join().expect("latency thread"));
    }
    let secs = start.elapsed().as_secs_f64();
    LatencyResult {
        achieved_ops_per_sec: (cfg.threads as u64 * cfg.ops_per_thread) as f64 / secs,
        hist,
    }
}

pub fn run_latency_lock(name: &str, cfg: &LatencyConfig) -> LatencyResult {
    match name {
        "mutex" => {
            let objs: Arc<Vec<CachePadded<Mutex<u64>>>> = Arc::new(
                (0..cfg.objects).map(|_| CachePadded::new(Mutex::new(0))).collect(),
            );
            run_lock_open_loop(cfg, objs, |o, i| {
                let mut g = o[i].lock().unwrap();
                pause();
                *g += 1;
            })
        }
        "spin" => {
            let objs: Arc<Vec<CachePadded<LockCell<SpinLock, u64>>>> = Arc::new(
                (0..cfg.objects).map(|_| CachePadded::new(LockCell::new(0))).collect(),
            );
            run_lock_open_loop(cfg, objs, |o, i| {
                o[i].with_lock(|c| {
                    pause();
                    *c += 1;
                });
            })
        }
        "mcs" => {
            let objs: Arc<Vec<CachePadded<LockCell<McsLock, u64>>>> = Arc::new(
                (0..cfg.objects).map(|_| CachePadded::new(LockCell::new(0))).collect(),
            );
            run_lock_open_loop(cfg, objs, |o, i| {
                o[i].with_lock(|c| {
                    pause();
                    *c += 1;
                });
            })
        }
        other => panic!("unknown lock {other:?}"),
    }
}

/// Delegation open-loop run: one pacing fiber per client worker issues
/// `apply_then` at scheduled arrivals; completion callbacks record latency
/// from the scheduled time.
pub fn run_latency_trust(cfg: &LatencyConfig) -> LatencyResult {
    let fcfg = FaddConfig {
        threads: cfg.threads,
        objects: cfg.objects,
        dedicated: cfg.dedicated,
        ..Default::default()
    };
    let workers = fcfg.dedicated + fcfg.threads;
    let rt = crate::runtime::Runtime::builder()
        .workers(workers)
        .dedicated_trustees(fcfg.dedicated)
        .build();
    let trustee_ids: Vec<usize> = if fcfg.dedicated > 0 {
        (0..fcfg.dedicated).collect()
    } else {
        (0..workers).collect()
    };
    let counters: Arc<Vec<Trust<u64>>> = Arc::new(
        (0..cfg.objects)
            .map(|o| rt.trustee(trustee_ids[o % trustee_ids.len()]).entrust(0u64))
            .collect(),
    );
    let clients: Vec<usize> = (fcfg.dedicated..workers).collect();
    let per_client_rate = cfg.offered_ops_per_sec / clients.len() as f64;
    let interval = Duration::from_secs_f64(1.0 / per_client_rate);
    let ops_per_client = cfg.ops_per_thread * cfg.threads as u64 / clients.len() as u64;

    let done = Arc::new(AtomicU64::new(0));
    let hists: Arc<Mutex<Vec<LatencyHist>>> = Arc::new(Mutex::new(Vec::new()));
    let t0 = Instant::now();
    for (ci, &w) in clients.iter().enumerate() {
        let counters = counters.clone();
        let done = done.clone();
        let hists = hists.clone();
        let cfg2 = cfg.clone();
        rt.spawn_on(w, move || {
            let mut rng = Rng::new(cfg2.seed ^ (ci as u64) << 7);
            let dist = KeyDist::from_spec(&cfg2.dist, cfg2.objects as u64);
            let hist = std::rc::Rc::new(std::cell::RefCell::new(LatencyHist::new()));
            let completed = std::rc::Rc::new(std::cell::Cell::new(0u64));
            let start = Instant::now();
            let mut issued = 0u64;
            while completed.get() < ops_per_client {
                let scheduled = start + interval.mul_f64(issued as f64);
                if issued < ops_per_client && Instant::now() >= scheduled {
                    let obj = dist.sample(&mut rng) as usize;
                    let h = hist.clone();
                    let comp = completed.clone();
                    counters[obj].apply_then(
                        |c| {
                            pause();
                            *c += 1;
                            *c
                        },
                        move |_| {
                            h.borrow_mut().record(scheduled.elapsed().as_nanos() as u64);
                            comp.set(comp.get() + 1);
                        },
                    );
                    issued += 1;
                } else {
                    crate::fiber::yield_now();
                }
            }
            hists.lock().unwrap().push(hist.borrow().clone());
            done.fetch_add(1, Ordering::AcqRel);
        });
    }
    while done.load(Ordering::Acquire) != clients.len() as u64 {
        std::thread::yield_now();
    }
    let secs = t0.elapsed().as_secs_f64();
    let mut hist = LatencyHist::new();
    for h in hists.lock().unwrap().iter() {
        hist.merge(h);
    }
    let total_ops = ops_per_client * clients.len() as u64;
    drop(counters);
    rt.shutdown();
    LatencyResult { achieved_ops_per_sec: total_ops as f64 / secs, hist }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn quick_cfg() -> LatencyConfig {
        LatencyConfig {
            threads: 2,
            objects: 8,
            offered_ops_per_sec: 50_000.0,
            ops_per_thread: 300,
            dist: "uniform".into(),
            seed: 1,
            dedicated: 0,
        }
    }

    #[test]
    fn lock_latency_records_all_ops() {
        for name in ["mutex", "spin", "mcs"] {
            let r = run_latency_lock(name, &quick_cfg());
            assert_eq!(r.hist.count(), 600, "{name}");
            assert!(r.mean_us() > 0.0);
            assert!(r.p999_us() >= r.mean_us() / 10.0);
        }
    }

    #[test]
    fn trust_latency_records_all_ops() {
        let r = run_latency_trust(&quick_cfg());
        assert_eq!(r.hist.count(), 600);
        assert!(r.achieved_ops_per_sec > 0.0);
    }

    #[test]
    fn overload_inflates_latency() {
        // At absurd offered load the system saturates; latency at the
        // tail must exceed the uncontended mean noticeably.
        let mut cfg = quick_cfg();
        cfg.offered_ops_per_sec = 1e9; // far beyond capacity
        let r = run_latency_lock("mutex", &cfg);
        // The system cannot meet an absurd offered rate: achieved must be
        // far below offered, and open-loop queueing must show up in the
        // tail (p99.9 >> best case).
        assert!(r.achieved_ops_per_sec < 1e8, "achieved {}", r.achieved_ops_per_sec);
        assert!(r.hist.quantile(0.999) > r.hist.min());
    }
}
