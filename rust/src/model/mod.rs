//! Deterministic interleaving explorer behind the `model` cargo feature —
//! a zero-dependency, loom-style model checker for the crate's hand-rolled
//! Acquire/Release protocols.
//!
//! ## What it does
//!
//! [`explore`] runs a closed-world *model* (a closure that spawns a handful
//! of virtual threads via [`spawn`]) once per thread schedule, enumerating
//! schedules by depth-first search over the scheduling decisions taken at
//! every shared-memory operation. Shared state must go through the
//! [`crate::util::vatomic`] shim ([`VAtomicU64`](crate::util::vatomic::VAtomicU64),
//! [`VBool`](crate::util::vatomic::VBool),
//! [`VCell`](crate::util::vatomic::VCell)): each access is a *yield point*
//! where the explorer decides which thread runs next.
//!
//! Violations the explorer reports, each with a replayable schedule:
//!
//! - **data race / torn read** — a [`VCell`](crate::util::vatomic::VCell)
//!   access not ordered (by a release-store → acquire-load edge on some
//!   virtual atomic) after the last conflicting access;
//! - **use-after-free / double-free** — via the tracked-allocation API
//!   ([`track_alloc`] / [`track_access`] / [`track_free`]);
//! - **deadlock** — every live thread parked in [`block_until`];
//! - **assertion failure** — any panic inside a virtual thread.
//!
//! ## How ordering bugs are caught under sequential exploration
//!
//! The explorer executes every schedule *sequentially consistently*: it
//! never simulates store buffering or reordering. Instead it tracks
//! happens-before with per-thread vector clocks: a `Release` store
//! deposits the writer's clock on the atomic, an `Acquire` load of that
//! value joins it into the reader's clock, and `Relaxed` transfers
//! nothing. A payload write published by a `Relaxed`-downgraded store
//! therefore has *no* happens-before edge to the consumer's read, and the
//! consumer's `VCell` read is reported as a potential torn read — exactly
//! the class of bug weakening a publish store introduces on real
//! hardware, caught without ever executing a weak behaviour. The honest
//! gap: behaviours that require a *value* to be reordered (e.g. IRIW) are
//! out of scope; see DESIGN.md "Correctness tooling".
//!
//! ## Scheduling
//!
//! One OS thread per virtual thread, but exactly one runs at a time; all
//! others park on a condvar. At each yield point the *running* thread
//! consults the DFS state and either continues or hands off — there is no
//! controller round-trip on the hot path, so exploring tens of thousands
//! of schedules takes seconds. Schedules are enumerated with a
//! *preemption bound* ([`Opts::preemptions`]): switching away from a
//! still-runnable thread costs one preemption, switches forced by a block
//! or exit are free. Small bounds (2–3) are known to expose the vast
//! majority of concurrency bugs while keeping the schedule space
//! tractable.

use std::cell::RefCell;
use std::collections::VecDeque;
use std::panic::{self, AssertUnwindSafe};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;

/// Sentinel: no thread currently holds the virtual CPU.
const NOBODY: usize = usize::MAX;

/// Marker payload used to unwind virtual threads when a run aborts
/// (violation found elsewhere, or exploration shutting down). Carried via
/// `resume_unwind` so the panic hook stays silent.
struct AbortRun;

/// Exploration options.
#[derive(Clone, Copy, Debug)]
pub struct Opts {
    /// Maximum number of *preemptive* context switches per schedule
    /// (switching away from a runnable thread). Forced switches (block,
    /// exit) are free. 2 is enough for every seeded bug in this crate's
    /// models; raise it to widen coverage.
    pub preemptions: usize,
    /// Hard cap on schedules explored; [`Report::completed`] is `false`
    /// if the DFS was truncated by this cap.
    pub max_schedules: u64,
    /// Per-schedule step cap (yield points executed); exceeding it is
    /// reported as a livelock violation.
    pub max_steps: u64,
}

impl Default for Opts {
    fn default() -> Opts {
        Opts { preemptions: 2, max_schedules: 500_000, max_steps: 20_000 }
    }
}

/// A violation found by the explorer.
#[derive(Clone, Debug)]
pub struct Violation {
    /// Human-readable description (race, UAF, deadlock, assertion text).
    pub message: String,
    /// The thread chosen at each branching decision point, in order.
    /// Feed to [`replay`] to reproduce the failing schedule.
    pub schedule: Vec<usize>,
}

/// Result of an [`explore`] call.
#[derive(Clone, Debug)]
pub struct Report {
    /// Schedules fully executed.
    pub schedules: u64,
    /// `true` iff the DFS exhausted every schedule within the preemption
    /// bound (i.e. was not truncated by `max_schedules`).
    pub completed: bool,
    /// Deepest branching-decision stack seen.
    pub max_depth: usize,
    /// First violation found, if any; exploration stops at the first.
    pub violation: Option<Violation>,
}

impl Report {
    /// Panic with the violation message if one was found.
    pub fn assert_ok(&self) {
        if let Some(v) = &self.violation {
            panic!("model violation: {} (schedule {:?})", v.message, v.schedule);
        }
    }
}

// ---------------------------------------------------------------------------
// Per-run state
// ---------------------------------------------------------------------------

#[derive(Clone, Copy, PartialEq, Eq, Debug)]
enum Status {
    Runnable,
    Blocked,
    Finished,
}

/// Raw pointer to a caller-owned `block_until` predicate. Stored in the
/// shared run state so that *other* threads (the ones taking scheduling
/// decisions) can re-evaluate it.
struct PredPtr(*const (dyn Fn() -> bool + 'static));

// SAFETY: the pointee lives in the stack frame of a virtual thread that is
// parked inside `block_until` for as long as its status is `Blocked`; the
// pointer is only dereferenced under the run lock while that status holds,
// and is cleared before the owner is released. Predicates only read
// `VAtomic*` raw values, so evaluation from another OS thread is sound.
unsafe impl Send for PredPtr {}

/// One DFS decision point: the candidate threads and which one this run
/// takes.
struct Choice {
    options: Vec<usize>,
    index: usize,
}

/// Per-registered-variable metadata for happens-before tracking.
struct VarState {
    /// Vector clock deposited by the release-store that wrote the current
    /// value (empty after a `Relaxed` store).
    release: Vec<u32>,
    /// Last non-atomic write: `(thread, clock-at-write)`.
    last_write: Option<(usize, u32)>,
    /// Per-thread clock of the last non-atomic read (0 = never read).
    reads: Vec<u32>,
}

struct AllocState {
    name: &'static str,
    alive: bool,
}

struct RunState {
    // --- persistent across runs ---
    /// Monotone run counter; also the registration generation for
    /// `VarId`s (variables re-register on their first access each run).
    generation: u64,
    /// DFS stack of branching decision points, kept across runs.
    stack: Vec<Choice>,
    /// When replaying: the forced schedule (thread id per branching
    /// decision), instead of DFS enumeration.
    forced: Option<Vec<usize>>,
    max_depth: usize,

    // --- reset every run ---
    active: bool,
    abort: bool,
    status: Vec<Status>,
    preds: Vec<Option<PredPtr>>,
    current: usize,
    /// Branching decisions taken this run (thread ids), for replay.
    chosen: Vec<usize>,
    /// Index of the next branching decision (into `stack` / `forced`).
    depth: usize,
    preemptions_used: usize,
    steps: u64,
    violation: Option<Violation>,
    /// Vector clocks, `clocks[t][u]`.
    clocks: Vec<Vec<u32>>,
    vars: Vec<VarState>,
    allocs: Vec<AllocState>,
    handles: Vec<JoinHandle<()>>,
    /// Threads spawned but not yet started are identified positionally;
    /// spawn is setup-phase only, so ids are assigned deterministically.
    nthreads: usize,
}

impl RunState {
    fn reset_for_run(&mut self) {
        self.generation += 1;
        self.active = false;
        self.abort = false;
        self.status.clear();
        self.preds.clear();
        self.current = NOBODY;
        self.chosen.clear();
        self.depth = 0;
        self.preemptions_used = 0;
        self.steps = 0;
        self.violation = None;
        self.clocks.clear();
        self.vars.clear();
        self.allocs.clear();
        self.nthreads = 0;
        debug_assert!(self.handles.is_empty());
    }

    fn all_finished(&self) -> bool {
        self.status.iter().all(|s| *s == Status::Finished)
    }

    fn record_violation(&mut self, message: String) {
        if self.violation.is_none() {
            self.violation =
                Some(Violation { message, schedule: self.chosen.clone() });
        }
        self.abort = true;
    }

    /// Join clock `src` into `dst` (element-wise max).
    fn join(dst: &mut Vec<u32>, src: &[u32]) {
        if dst.len() < src.len() {
            dst.resize(src.len(), 0);
        }
        for (d, s) in dst.iter_mut().zip(src.iter()) {
            *d = (*d).max(*s);
        }
    }
}

/// Shared run context: one per `explore()` call, shared by the controller
/// and every virtual thread.
pub(crate) struct Ctx {
    m: Mutex<RunState>,
    cv: Condvar,
    opts: Opts,
}

// ---------------------------------------------------------------------------
// Thread-local identity
// ---------------------------------------------------------------------------

#[derive(Clone)]
enum Role {
    /// The controller thread while the model body runs (single-threaded
    /// construction phase): shim accesses go straight to memory, no
    /// scheduling, no clocks.
    Setup(Arc<Ctx>),
    /// A virtual thread with its id.
    VThread(Arc<Ctx>, usize),
}

thread_local! {
    static ROLE: RefCell<Option<Role>> = const { RefCell::new(None) };
}

fn current_role() -> Option<Role> {
    ROLE.with(|r| r.borrow().clone())
}

// ---------------------------------------------------------------------------
// Variable registration (used by util::vatomic)
// ---------------------------------------------------------------------------

/// Per-shim-object registration slot: packs `(generation << 32) | (index+1)`
/// so that objects living across runs (or reused from a previous explore)
/// re-register lazily on first access of each run.
pub struct VarId(AtomicU64);

impl VarId {
    pub const fn unregistered() -> VarId {
        VarId(AtomicU64::new(0))
    }
}

impl Default for VarId {
    fn default() -> Self {
        VarId::unregistered()
    }
}

fn var_index(st: &mut RunState, vid: &VarId) -> usize {
    let packed = vid.0.load(Ordering::Relaxed);
    let (gen, idx1) = (packed >> 32, (packed & 0xffff_ffff) as usize);
    if gen == st.generation && idx1 != 0 {
        return idx1 - 1;
    }
    let idx = st.vars.len();
    st.vars.push(VarState {
        release: Vec::new(),
        last_write: None,
        reads: vec![0; st.nthreads],
    });
    vid.0
        .store((st.generation << 32) | (idx as u64 + 1), Ordering::Relaxed);
    idx
}

// ---------------------------------------------------------------------------
// Scheduling core
// ---------------------------------------------------------------------------

/// Compute the candidate set for the next decision. Blocked threads whose
/// predicate currently holds are candidates (they are unblocked if and
/// when chosen). Returns `(options, forced_switch)`.
fn candidates(st: &RunState, prev: usize) -> Vec<usize> {
    let mut opts = Vec::with_capacity(st.nthreads);
    let prev_runnable =
        prev != NOBODY && st.status[prev] == Status::Runnable;
    // Keep `prev` first so that "continue the current thread" is always
    // option 0 — DFS then explores the no-preemption schedule first.
    if prev_runnable {
        opts.push(prev);
    }
    for t in 0..st.nthreads {
        if prev_runnable && t == prev {
            continue;
        }
        match st.status[t] {
            Status::Runnable => opts.push(t),
            Status::Blocked => {
                let ready = match &st.preds[t] {
                    // SAFETY: see `PredPtr` — the predicate outlives the
                    // Blocked status and we hold the run lock.
                    Some(p) => unsafe { (*p.0)() },
                    None => false,
                };
                if ready {
                    opts.push(t);
                }
            }
            Status::Finished => {}
        }
    }
    opts
}

/// Take the next scheduling decision. Called with the run lock held, by
/// the thread that currently owns the virtual CPU (or the controller for
/// the initial decision). Grants the CPU to the chosen thread.
///
/// Returns the chosen thread, or `None` when every thread has finished.
/// Detects deadlock (live threads, no candidates).
fn decide_next(ctx: &Ctx, st: &mut RunState, prev: usize) -> Option<usize> {
    if st.all_finished() {
        st.current = NOBODY;
        ctx.cv.notify_all();
        return None;
    }
    let mut opts = candidates(st, prev);
    let prev_runnable =
        prev != NOBODY && st.status[prev] == Status::Runnable;
    // Preemption bound: once exhausted, a runnable thread must continue.
    if prev_runnable && st.preemptions_used >= ctx.opts.preemptions {
        opts.truncate(1); // opts[0] == prev
    }
    if opts.is_empty() {
        let parked: Vec<usize> = (0..st.nthreads)
            .filter(|&t| st.status[t] == Status::Blocked)
            .collect();
        st.record_violation(format!(
            "deadlock: threads {:?} blocked with no runnable thread",
            parked
        ));
        st.current = NOBODY;
        ctx.cv.notify_all();
        return None;
    }
    let pick = if opts.len() == 1 {
        opts[0]
    } else {
        // Branching decision: consult replay schedule or DFS stack.
        let d = st.depth;
        st.depth += 1;
        st.max_depth = st.max_depth.max(st.depth);
        let tid = if let Some(forced) = &st.forced {
            let want = forced.get(d).copied().unwrap_or(opts[0]);
            if opts.contains(&want) {
                want
            } else {
                opts[0]
            }
        } else {
            if d == st.stack.len() {
                st.stack.push(Choice { options: opts.clone(), index: 0 });
            }
            let c = &st.stack[d];
            debug_assert_eq!(
                c.options, opts,
                "nondeterministic model: decision {d} options changed between runs"
            );
            c.options[c.index]
        };
        st.chosen.push(tid);
        tid
    };
    if prev_runnable && pick != prev {
        st.preemptions_used += 1;
    }
    if st.status[pick] == Status::Blocked {
        st.status[pick] = Status::Runnable;
        st.preds[pick] = None;
    }
    st.current = pick;
    if pick != prev {
        ctx.cv.notify_all();
    }
    Some(pick)
}

/// Park the calling virtual thread until it owns the virtual CPU (or the
/// run aborts, in which case unwind). Lock is held on entry and exit.
fn wait_for_cpu<'a>(
    ctx: &Ctx,
    mut guard: std::sync::MutexGuard<'a, RunState>,
    me: usize,
) -> std::sync::MutexGuard<'a, RunState> {
    while !guard.abort && guard.current != me {
        guard = ctx
            .cv
            .wait(guard)
            .unwrap_or_else(|e| e.into_inner());
    }
    if guard.abort {
        drop(guard);
        panic::resume_unwind(Box::new(AbortRun));
    }
    guard
}

/// The common prologue of every model event executed by a virtual thread:
/// take a scheduling decision at this yield point, hand off if another
/// thread is chosen, and return with the lock held and the CPU owned.
fn yield_point<'a>(ctx: &'a Ctx, me: usize) -> std::sync::MutexGuard<'a, RunState> {
    let mut guard = ctx.m.lock().unwrap_or_else(|e| e.into_inner());
    if guard.abort {
        drop(guard);
        panic::resume_unwind(Box::new(AbortRun));
    }
    debug_assert_eq!(guard.current, me, "yield point on a thread without the CPU");
    guard.steps += 1;
    if guard.steps > ctx.opts.max_steps {
        guard.record_violation(format!(
            "step limit exceeded ({} yield points): livelock or unbounded spin \
             — use model::block_until instead of spinning",
            ctx.opts.max_steps
        ));
        ctx.cv.notify_all();
        drop(guard);
        panic::resume_unwind(Box::new(AbortRun));
    }
    match decide_next(ctx, &mut guard, me) {
        Some(pick) if pick == me => guard,
        _ => wait_for_cpu(ctx, guard, me),
    }
}

/// Bump the acting thread's clock component after an event.
fn tick(st: &mut RunState, me: usize) {
    st.clocks[me][me] += 1;
}

// ---------------------------------------------------------------------------
// Events (called from util::vatomic and the tracked-alloc API)
// ---------------------------------------------------------------------------

fn is_release(o: Ordering) -> bool {
    matches!(o, Ordering::Release | Ordering::AcqRel | Ordering::SeqCst)
}

fn is_acquire(o: Ordering) -> bool {
    matches!(o, Ordering::Acquire | Ordering::AcqRel | Ordering::SeqCst)
}

/// Atomic load through the shim. Setup phase / no model context: plain
/// load. Virtual thread: yield point + happens-before bookkeeping.
pub(crate) fn atomic_load(vid: &VarId, inner: &AtomicU64, order: Ordering) -> u64 {
    match current_role() {
        Some(Role::VThread(ctx, me)) => {
            let mut st = yield_point(&ctx, me);
            let idx = var_index(&mut st, vid);
            let v = inner.load(Ordering::SeqCst);
            if is_acquire(order) {
                let rel = std::mem::take(&mut st.vars[idx].release);
                RunState::join(&mut st.clocks[me], &rel);
                st.vars[idx].release = rel;
            }
            tick(&mut st, me);
            v
        }
        _ => inner.load(order),
    }
}

/// Atomic store through the shim.
pub(crate) fn atomic_store(vid: &VarId, inner: &AtomicU64, val: u64, order: Ordering) {
    match current_role() {
        Some(Role::VThread(ctx, me)) => {
            let mut st = yield_point(&ctx, me);
            let idx = var_index(&mut st, vid);
            if is_release(order) {
                let clock = st.clocks[me].clone();
                st.vars[idx].release = clock;
            } else {
                // A Relaxed store breaks the release chain: a subsequent
                // acquire load of *this* value synchronizes with nothing.
                st.vars[idx].release.clear();
            }
            inner.store(val, Ordering::SeqCst);
            tick(&mut st, me);
            drop(st);
        }
        _ => inner.store(val, order),
    }
}

/// Outcome of a VCell access check; the caller performs the raw memory
/// access *after* this returns (it still owns the virtual CPU until its
/// next yield point, so the access is exclusive).
pub(crate) fn cell_write(vid: &VarId) {
    let role = current_role();
    match role {
        Some(Role::VThread(ctx, me)) => {
            let mut st = yield_point(&ctx, me);
            let idx = var_index(&mut st, vid);
            let mut race: Option<String> = None;
            if let Some((wt, wc)) = st.vars[idx].last_write {
                if wt != me && st.clocks[me].get(wt).copied().unwrap_or(0) < wc {
                    race = Some(format!(
                        "data race: write by thread {me} not ordered after \
                         write by thread {wt} (missing release/acquire edge)"
                    ));
                }
            }
            if race.is_none() {
                for (u, &rc) in st.vars[idx].reads.clone().iter().enumerate() {
                    if u != me && rc > 0 && st.clocks[me].get(u).copied().unwrap_or(0) < rc {
                        race = Some(format!(
                            "data race: write by thread {me} not ordered after \
                             read by thread {u} (missing release/acquire edge)"
                        ));
                        break;
                    }
                }
            }
            if let Some(msg) = race {
                st.record_violation(msg);
                ctx.cv.notify_all();
                drop(st);
                panic::resume_unwind(Box::new(AbortRun));
            }
            let epoch = st.clocks[me][me];
            st.vars[idx].last_write = Some((me, epoch));
            tick(&mut st, me);
        }
        Some(Role::Setup(_)) => {}
        None => panic!("VCell accessed outside a model (build with the protocol, not production code)"),
    }
}

pub(crate) fn cell_read(vid: &VarId) {
    let role = current_role();
    match role {
        Some(Role::VThread(ctx, me)) => {
            let mut st = yield_point(&ctx, me);
            let idx = var_index(&mut st, vid);
            if let Some((wt, wc)) = st.vars[idx].last_write {
                if wt != me && st.clocks[me].get(wt).copied().unwrap_or(0) < wc {
                    let msg = format!(
                        "torn read: read by thread {me} races write by thread {wt} \
                         (missing release/acquire edge)"
                    );
                    st.record_violation(msg);
                    ctx.cv.notify_all();
                    drop(st);
                    panic::resume_unwind(Box::new(AbortRun));
                }
            }
            let epoch = st.clocks[me][me];
            st.vars[idx].reads[me] = epoch;
            tick(&mut st, me);
        }
        Some(Role::Setup(_)) => {}
        None => panic!("VCell accessed outside a model (build with the protocol, not production code)"),
    }
}

// ---------------------------------------------------------------------------
// Public model-building API
// ---------------------------------------------------------------------------

/// Spawn a virtual thread. Only valid during the model body (setup
/// phase); all threads must exist before the first one runs, which keeps
/// thread ids — and therefore schedules — deterministic.
pub fn spawn<F: FnOnce() + Send + 'static>(f: F) {
    let ctx = match current_role() {
        Some(Role::Setup(ctx)) => ctx,
        Some(Role::VThread(..)) => {
            panic!("model::spawn called from a virtual thread; spawn all threads in the model body")
        }
        None => panic!("model::spawn outside model::explore"),
    };
    let id;
    {
        let mut st = ctx.m.lock().unwrap_or_else(|e| e.into_inner());
        id = st.nthreads;
        st.nthreads += 1;
        st.status.push(Status::Runnable);
        st.preds.push(None);
    }
    let tctx = Arc::clone(&ctx);
    let handle = std::thread::Builder::new()
        .name(format!("vthread-{id}"))
        .spawn(move || {
            ROLE.with(|r| *r.borrow_mut() = Some(Role::VThread(Arc::clone(&tctx), id)));
            // Wait for the controller to start the run and for this thread
            // to be granted the CPU the first time.
            {
                let guard = tctx.m.lock().unwrap_or_else(|e| e.into_inner());
                let mut guard = guard;
                while !guard.abort && !(guard.active && guard.current == id) {
                    guard = tctx.cv.wait(guard).unwrap_or_else(|e| e.into_inner());
                }
                let aborted = guard.abort;
                drop(guard);
                if aborted {
                    finish_thread(&tctx, id, None);
                    return;
                }
            }
            let result = panic::catch_unwind(AssertUnwindSafe(f));
            let failure = match result {
                Ok(()) => None,
                Err(payload) => {
                    if payload.downcast_ref::<AbortRun>().is_some() {
                        None
                    } else if let Some(s) = payload.downcast_ref::<&str>() {
                        Some((*s).to_string())
                    } else if let Some(s) = payload.downcast_ref::<String>() {
                        Some(s.clone())
                    } else {
                        Some("virtual thread panicked (non-string payload)".into())
                    }
                }
            };
            finish_thread(&tctx, id, failure);
        })
        .expect("failed to spawn model thread");
    ctx.m.lock().unwrap_or_else(|e| e.into_inner()).handles.push(handle);
}

fn finish_thread(ctx: &Ctx, id: usize, failure: Option<String>) {
    let mut st = ctx.m.lock().unwrap_or_else(|e| e.into_inner());
    if let Some(msg) = failure {
        st.record_violation(format!("thread {id} panicked: {msg}"));
    }
    st.status[id] = Status::Finished;
    st.preds[id] = None;
    if st.current == id || st.abort {
        // Hand the CPU onward (or wake everyone for abort/run-end).
        if st.abort {
            st.current = NOBODY;
            ctx.cv.notify_all();
        } else {
            decide_next(ctx, &mut st, id);
        }
    }
    ctx.cv.notify_all();
}

/// Park the calling virtual thread until `pred` holds. The predicate is
/// re-evaluated (under the run lock, by whichever thread is taking a
/// scheduling decision) at every subsequent yield point; when it holds,
/// this thread becomes schedulable again. `pred` must only read shim
/// values via the `raw_load` accessors — it runs outside the scheduled
/// thread and must not take yield points.
///
/// Replaces unbounded spin loops in models: a spin loop would make the
/// schedule space infinite, and a spin that can never be satisfied
/// becomes a detectable deadlock instead of a hang.
pub fn block_until<P: Fn() -> bool>(pred: P) {
    let (ctx, me) = match current_role() {
        Some(Role::VThread(ctx, me)) => (ctx, me),
        _ => panic!("model::block_until outside a virtual thread"),
    };
    let mut st = yield_point(&ctx, me);
    if pred() {
        tick(&mut st, me);
        return;
    }
    let ptr: *const (dyn Fn() -> bool) = &pred;
    // SAFETY: only the lifetime is transmuted away. We park in this frame
    // until the scheduler clears the predicate slot and grants us the CPU
    // (or aborts), so `pred` outlives every dereference; see `PredPtr`.
    let ptr: *const (dyn Fn() -> bool + 'static) = unsafe { std::mem::transmute(ptr) };
    st.status[me] = Status::Blocked;
    st.preds[me] = Some(PredPtr(ptr));
    // Hand off; we are not runnable, so this is a forced switch.
    decide_next(&ctx, &mut st, me);
    let mut st = wait_for_cpu(&ctx, st, me);
    // Scheduler only grants a blocked thread after seeing `pred()` true,
    // and nothing ran in between.
    debug_assert!(st.status[me] == Status::Runnable);
    tick(&mut st, me);
}

/// A plain yield point with no memory effect: lets the explorer consider
/// a context switch here.
pub fn yield_now() {
    if let Some(Role::VThread(ctx, me)) = current_role() {
        let mut st = yield_point(&ctx, me);
        tick(&mut st, me);
    }
}

// ---------------------------------------------------------------------------
// Tracked allocations (use-after-free / double-free detection)
// ---------------------------------------------------------------------------

/// Register a model-level allocation; returns its id. Allowed in the
/// setup phase and in virtual threads.
pub fn track_alloc(name: &'static str) -> usize {
    match current_role() {
        Some(Role::VThread(ctx, me)) => {
            let mut st = yield_point(&ctx, me);
            let id = st.allocs.len();
            st.allocs.push(AllocState { name, alive: true });
            tick(&mut st, me);
            id
        }
        Some(Role::Setup(ctx)) => {
            let mut st = ctx.m.lock().unwrap_or_else(|e| e.into_inner());
            let id = st.allocs.len();
            st.allocs.push(AllocState { name, alive: true });
            id
        }
        None => panic!("model::track_alloc outside model::explore"),
    }
}

fn alloc_event(op: &str, id: usize, freeing: bool) {
    let (ctx, me) = match current_role() {
        Some(Role::VThread(ctx, me)) => (ctx, me),
        Some(Role::Setup(_)) => panic!("tracked allocations may only be {op}ed by virtual threads"),
        None => panic!("model::track_{op} outside model::explore"),
    };
    let mut st = yield_point(&ctx, me);
    let a = &mut st.allocs[id];
    if !a.alive {
        let msg = if freeing {
            format!("double-free of tracked allocation `{}` by thread {me}", a.name)
        } else {
            format!("use-after-free: thread {me} accessed freed allocation `{}`", a.name)
        };
        st.record_violation(msg);
        ctx.cv.notify_all();
        drop(st);
        panic::resume_unwind(Box::new(AbortRun));
    }
    if freeing {
        a.alive = false;
    }
    tick(&mut st, me);
}

/// Record an access to a tracked allocation; a violation if it was freed.
pub fn track_access(id: usize) {
    alloc_event("access", id, false);
}

/// Free a tracked allocation; a violation if already freed.
pub fn track_free(id: usize) {
    alloc_event("free", id, true);
}

/// Is the tracked allocation still alive? For end-of-model assertions
/// (e.g. "the spill buffer was freed exactly once").
pub fn tracked_alive(id: usize) -> bool {
    match current_role() {
        Some(Role::VThread(ctx, _)) | Some(Role::Setup(ctx)) => {
            let st = ctx.m.lock().unwrap_or_else(|e| e.into_inner());
            st.allocs[id].alive
        }
        None => panic!("model::tracked_alive outside model::explore"),
    }
}

// ---------------------------------------------------------------------------
// The explorer
// ---------------------------------------------------------------------------

fn install_quiet_panic_hook() {
    static ONCE: std::sync::Once = std::sync::Once::new();
    ONCE.call_once(|| {
        let prev = panic::take_hook();
        panic::set_hook(Box::new(move |info| {
            // Virtual-thread panics are converted into model violations;
            // suppress their default stderr spew. Everything else goes to
            // the previous hook.
            let in_model = ROLE.with(|r| {
                matches!(r.borrow().as_ref(), Some(Role::VThread(..)))
            });
            if !in_model {
                prev(info);
            }
        }));
    });
}

/// Execute one schedule. The DFS stack in `st` supplies the branching
/// decisions; new decision points are appended with index 0.
fn run_once(ctx: &Arc<Ctx>, body: &mut dyn FnMut()) -> (Option<Violation>, u64) {
    {
        let mut st = ctx.m.lock().unwrap_or_else(|e| e.into_inner());
        st.reset_for_run();
    }
    ROLE.with(|r| *r.borrow_mut() = Some(Role::Setup(Arc::clone(ctx))));
    let body_result = panic::catch_unwind(AssertUnwindSafe(body));
    ROLE.with(|r| *r.borrow_mut() = None);

    let handles;
    {
        let mut st = ctx.m.lock().unwrap_or_else(|e| e.into_inner());
        let n = st.nthreads;
        st.clocks = vec![vec![0; n]; n];
        for t in 0..n {
            st.clocks[t][t] = 1;
        }
        for v in &mut st.vars {
            v.reads.resize(n, 0);
        }
        if body_result.is_err() {
            st.record_violation("model body panicked during setup".into());
        }
        st.active = true;
        if st.violation.is_none() {
            // Initial decision: which thread runs first.
            decide_next(ctx, &mut st, NOBODY);
        }
        ctx.cv.notify_all();
        while !st.all_finished() {
            st = ctx.cv.wait(st).unwrap_or_else(|e| e.into_inner());
        }
        handles = std::mem::take(&mut st.handles);
    }
    for h in handles {
        let _ = h.join();
    }
    let mut st = ctx.m.lock().unwrap_or_else(|e| e.into_inner());
    (st.violation.take(), st.steps)
}

/// Advance the persistent DFS stack to the next unexplored schedule.
/// Returns `false` when the space is exhausted.
fn advance_dfs(st: &mut RunState) -> bool {
    while let Some(top) = st.stack.last_mut() {
        if top.index + 1 < top.options.len() {
            top.index += 1;
            return true;
        }
        st.stack.pop();
    }
    false
}

fn new_ctx(opts: Opts, forced: Option<Vec<usize>>) -> Arc<Ctx> {
    Arc::new(Ctx {
        m: Mutex::new(RunState {
            generation: 0,
            stack: Vec::new(),
            forced,
            max_depth: 0,
            active: false,
            abort: false,
            status: Vec::new(),
            preds: Vec::new(),
            current: NOBODY,
            chosen: Vec::new(),
            depth: 0,
            preemptions_used: 0,
            steps: 0,
            violation: None,
            clocks: Vec::new(),
            vars: Vec::new(),
            allocs: Vec::new(),
            handles: Vec::new(),
            nthreads: 0,
        }),
        cv: Condvar::new(),
        opts,
    })
}

/// Explore every schedule of the model `body` (up to the preemption
/// bound), stopping at the first violation.
///
/// `body` runs once per schedule on the calling thread (the *setup
/// phase*): it builds the shared state and calls [`spawn`] for each
/// virtual thread. Shim accesses during setup hit memory directly.
pub fn explore(opts: Opts, mut body: impl FnMut()) -> Report {
    install_quiet_panic_hook();
    let ctx = new_ctx(opts, None);
    let mut schedules = 0u64;
    let mut violation = None;
    let mut completed = true;
    loop {
        let (v, _steps) = run_once(&ctx, &mut body);
        schedules += 1;
        if v.is_some() {
            violation = v;
            break;
        }
        let mut st = ctx.m.lock().unwrap_or_else(|e| e.into_inner());
        if !advance_dfs(&mut st) {
            break;
        }
        drop(st);
        if schedules >= opts.max_schedules {
            completed = false;
            break;
        }
    }
    let st = ctx.m.lock().unwrap_or_else(|e| e.into_inner());
    Report { schedules, completed, max_depth: st.max_depth, violation }
}

/// Re-execute a single schedule previously reported in a
/// [`Violation::schedule`]. Returns the violation it reproduces (if any).
pub fn replay(opts: Opts, schedule: &[usize], mut body: impl FnMut()) -> Option<Violation> {
    install_quiet_panic_hook();
    let ctx = new_ctx(opts, Some(schedule.to_vec()));
    let (v, _steps) = run_once(&ctx, &mut body);
    v
}

// ---------------------------------------------------------------------------
// Self-tests (compiled only with --features model)
// ---------------------------------------------------------------------------

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::vatomic::{VAtomicU64, VCell};
    use std::sync::atomic::Ordering::{Acquire, Relaxed, Release};

    /// Two increments through a shim atomic: every schedule completes,
    /// and the explorer enumerates more than one schedule.
    #[test]
    fn explores_multiple_schedules() {
        let report = explore(Opts::default(), || {
            let a = Arc::new(VAtomicU64::new(0));
            let (a1, a2) = (Arc::clone(&a), Arc::clone(&a));
            spawn(move || {
                let v = a1.load(Relaxed);
                a1.store(v + 1, Relaxed);
            });
            spawn(move || {
                let v = a2.load(Relaxed);
                a2.store(v + 1, Relaxed);
            });
        });
        report.assert_ok();
        assert!(report.completed, "tiny model must be exhaustible");
        assert!(report.schedules > 1, "two racing threads need >1 schedule");
    }

    /// The classic lost-update: both threads can read 0, so some schedule
    /// ends with counter == 1. Detected via an end-state assertion the
    /// explorer surfaces as a violation.
    #[test]
    fn finds_lost_update() {
        let report = explore(Opts::default(), || {
            let a = Arc::new(VAtomicU64::new(0));
            let done = Arc::new(VAtomicU64::new(0));
            for _ in 0..2 {
                let a = Arc::clone(&a);
                let done = Arc::clone(&done);
                spawn(move || {
                    let v = a.load(Relaxed);
                    a.store(v + 1, Relaxed);
                    let d = done.load(Relaxed);
                    done.store(d + 1, Relaxed);
                    if done.load(Relaxed) == 2 {
                        assert_eq!(a.load(Relaxed), 2, "lost update");
                    }
                });
            }
        });
        let v = report.violation.expect("explorer must find the lost update");
        assert!(v.message.contains("lost update"), "got: {}", v.message);
        // The schedule replays to the same violation.
        let r = replay(Opts::default(), &v.schedule, || {
            let a = Arc::new(VAtomicU64::new(0));
            let done = Arc::new(VAtomicU64::new(0));
            for _ in 0..2 {
                let a = Arc::clone(&a);
                let done = Arc::clone(&done);
                spawn(move || {
                    let v = a.load(Relaxed);
                    a.store(v + 1, Relaxed);
                    let d = done.load(Relaxed);
                    done.store(d + 1, Relaxed);
                    if done.load(Relaxed) == 2 {
                        assert_eq!(a.load(Relaxed), 2, "lost update");
                    }
                });
            }
        });
        assert!(r.is_some(), "replay must reproduce the violation");
    }

    /// Release/acquire publish is race-free; the same protocol with a
    /// Relaxed publish store is a torn read.
    #[test]
    fn relaxed_publish_is_a_torn_read() {
        let run = |publish_order: Ordering| {
            explore(Opts::default(), move || {
                let flag = Arc::new(VAtomicU64::new(0));
                let data = Arc::new(VCell::new(0u64));
                let (f1, d1) = (Arc::clone(&flag), Arc::clone(&data));
                spawn(move || {
                    d1.set(42);
                    f1.store(1, publish_order);
                });
                let (f2, d2) = (Arc::clone(&flag), Arc::clone(&data));
                spawn(move || {
                    block_until(move || f2.raw_load() == 1);
                    if f2.load(Acquire) == 1 {
                        assert_eq!(d2.get(), 42);
                    }
                });
            })
        };
        run(Release).assert_ok();
        let v = run(Relaxed).violation.expect("Relaxed publish must race");
        assert!(v.message.contains("race") || v.message.contains("torn"), "got: {}", v.message);
    }

    /// block_until on a condition nobody will ever make true is a
    /// detected deadlock, not a hang.
    #[test]
    fn detects_deadlock() {
        let report = explore(Opts::default(), || {
            let a = Arc::new(VAtomicU64::new(0));
            let a1 = Arc::clone(&a);
            spawn(move || {
                block_until(move || a1.raw_load() == 1);
            });
        });
        let v = report.violation.expect("must detect deadlock");
        assert!(v.message.contains("deadlock"), "got: {}", v.message);
    }

    /// Use-after-free through the tracked-allocation API.
    #[test]
    fn detects_use_after_free() {
        let report = explore(Opts::default(), || {
            let id = track_alloc("node");
            let gate = Arc::new(VAtomicU64::new(0));
            let g1 = Arc::clone(&gate);
            spawn(move || {
                track_free(id);
                g1.store(1, Release);
            });
            let g2 = Arc::clone(&gate);
            spawn(move || {
                block_until(move || g2.raw_load() == 1);
                let _ = g2.load(Acquire);
                track_access(id);
            });
        });
        let v = report.violation.expect("must detect UAF");
        assert!(v.message.contains("use-after-free"), "got: {}", v.message);
    }
}
