//! `trustee` — the Trust\<T\> launcher.
//!
//! Subcommands:
//!
//! ```text
//! trustee kv-server    --backend trust[:N]|mutex|rwlock|swift --workers W
//!                      --dedicated D --addr HOST:PORT [--prefill N]
//!                      [--val-len L] [--net epoll|busy|uring]
//!                      [--shed-high Q --shed-low Q] [--deadline-ms MS]
//!                      [--stall-ms MS] [--grace-ms MS] [--idle-ticks T]
//! trustee kv-load      --addr HOST:PORT --threads T --pipeline P --ops N
//!                      --keys K --dist uniform|zipf --write-pct W
//!                      [--val-len L] [--seed S] [--retry-shed]
//! trustee mcd-server   --backend trust[:N]|mutex|rwlock|swift --workers W
//!                      --dedicated D --addr HOST:PORT [--prefill N]
//!                      [--val-len L] [--budget-mb M] [--net epoll|busy|uring]
//!                      (--engine stock is accepted as an alias for
//!                       --backend mutex; exptime is honored)
//! trustee mcd-load     --addr HOST:PORT ... (same knobs as kv-load, plus
//!                      [--ttl-pct P]: % of sets carrying exptime 1)
//! trustee resp-server  --backend trust[:N]|mutex|rwlock|swift --workers W
//!                      --dedicated D --addr HOST:PORT [--prefill N]
//!                      [--val-len L] [--budget-mb M] [--net epoll|busy|uring]
//!                      (RESP2 — point redis-cli or any Redis client at it:
//!                       PING, GET, SET [EX|PX], DEL, EXISTS, MGET, INCR,
//!                       EXPIRE, PEXPIRE, TTL, PTTL, PERSIST, FLUSHALL)
//! trustee resp-load    --addr HOST:PORT ... (same knobs as kv-load, plus
//!                      [--ttl-pct P]: % of sets carrying EX 1)
//! trustee fadd         --engine mutex|spin|ticket|mcs|fc|trust|async
//!                      --threads T --objects O --ops N --dist D
//! trustee demo         quick in-process tour (Figure 1)
//! ```
//!
//! All three servers accept the same overload/robustness knobs
//! (`--shed-high/--shed-low` queue watermarks, `--deadline-ms`,
//! `--stall-ms`, `--grace-ms`, `--idle-ticks`; defaults =
//! [`ServerTuning::default`]), and all three loaders accept
//! `--retry-shed` to re-issue shed requests instead of counting them as
//! valueless completions.
//!
//! All three servers ride the shared delegated connection engine
//! (`trustee::server::engine`); the load generators report client-side
//! I/O failures descriptively and exit nonzero instead of panicking.

use trustee::bench::fadd::{run_async, run_lock_by_name, run_trust, FaddConfig};
use trustee::kvstore::{run_load, BackendKind, KvServer, KvServerConfig, LoadConfig};
use trustee::memcache::{run_memtier, McdServer, McdServerConfig, MemtierConfig};
use trustee::server::{run_resp_load, RespLoadConfig, RespServer, RespServerConfig, ServerTuning};
use trustee::util::cli::Args;
use trustee::util::stats::{fmt_mops, fmt_ns};

fn main() {
    let mut argv: Vec<String> = std::env::args().skip(1).collect();
    let cmd = if argv.is_empty() { "help".to_string() } else { argv.remove(0) };
    let args = Args::parse(argv);
    match cmd.as_str() {
        "kv-server" => kv_server(&args),
        "kv-load" => kv_load(&args),
        "mcd-server" => mcd_server(&args),
        "mcd-load" => mcd_load(&args),
        "resp-server" => resp_server(&args),
        "resp-load" => resp_load(&args),
        "fadd" => fadd(&args),
        "demo" => demo(),
        _ => {
            println!(
                "usage: trustee <kv-server|kv-load|mcd-server|mcd-load|resp-server|resp-load|\
                 fadd|demo> [--flags]"
            );
            println!("  kv-server / kv-load     binary KV protocol (out-of-order responses)");
            println!("  mcd-server / mcd-load   memcached text protocol (in-order)");
            println!("  resp-server / resp-load RESP2 (Redis) protocol (in-order)");
            println!("  fadd                    fetch-and-add microbench, demo: Figure 1 tour");
            println!("see the module docs in rust/src/main.rs for every knob");
        }
    }
}

/// Parse `--net`, exiting with the descriptive reason on an unknown spec
/// (like the other config errors; never a panic backtrace).
fn parse_net(args: &Args) -> trustee::kvstore::NetPolicy {
    trustee::kvstore::NetPolicy::from_spec(&args.get_str("net", "epoll")).unwrap_or_else(|e| {
        eprintln!("invalid --net: {e}");
        std::process::exit(1);
    })
}

/// Build the shared overload/robustness tuning from the server flags,
/// starting from the library defaults.
fn parse_tuning(args: &Args) -> ServerTuning {
    let d = ServerTuning::default();
    ServerTuning {
        shed_high: args.get("shed-high", d.shed_high),
        shed_low: args.get("shed-low", d.shed_low),
        deadline_ms: args.get("deadline-ms", d.deadline_ms),
        conn_stall_ms: args.get("stall-ms", d.conn_stall_ms),
        stop_drain_grace_ms: args.get("grace-ms", d.stop_drain_grace_ms),
        idle_ticks: args.get("idle-ticks", d.idle_ticks),
    }
}

/// Exit nonzero with every client-thread error when a load run failed.
fn bail_on_client_errors(errors: &[String]) {
    if !errors.is_empty() {
        for e in errors {
            eprintln!("client error: {e}");
        }
        std::process::exit(1);
    }
}

fn kv_server(args: &Args) {
    let server = KvServer::start(KvServerConfig {
        workers: args.get("workers", 4),
        dedicated: args.get("dedicated", 0),
        backend: BackendKind::from_spec(&args.get_str("backend", "trust")),
        addr: args.get_str("addr", "127.0.0.1:7878"),
        net: parse_net(args),
        tuning: parse_tuning(args),
    });
    let prefill: u64 = args.get("prefill", 0);
    if prefill > 0 {
        server.prefill(prefill, args.get("val-len", 16));
        println!("prefilled {prefill} keys");
    }
    println!(
        "kv server listening on {} ({}) (ctrl-c to stop)",
        server.addr(),
        server.net_info().summary()
    );
    loop {
        std::thread::sleep(std::time::Duration::from_secs(3600));
    }
}

fn kv_load(args: &Args) {
    let addr: std::net::SocketAddr = args
        .get_str("addr", "127.0.0.1:7878")
        .parse()
        .expect("bad --addr");
    let stats = run_load(&LoadConfig {
        addr,
        threads: args.get("threads", 2),
        pipeline: args.get("pipeline", 32),
        ops_per_thread: args.get("ops", 10_000),
        keys: args.get("keys", 1_000),
        dist: args.get_str("dist", "uniform"),
        write_pct: args.get("write-pct", 5),
        val_len: args.get("val-len", 16),
        seed: args.get("seed", 42),
        retry_shed: args.flag("retry-shed"),
    });
    bail_on_client_errors(&stats.errors);
    println!(
        "{} ops in {:.2}s = {} | mean {} p99.9 {} | hits {} misses {} shed {}",
        stats.ops,
        stats.elapsed.as_secs_f64(),
        fmt_mops(stats.throughput()),
        fmt_ns(stats.hist.mean()),
        fmt_ns(stats.hist.quantile(0.999) as f64),
        stats.hits,
        stats.misses,
        stats.shed
    );
}

fn mcd_server(args: &Args) {
    // --backend is the canonical selector; --engine stock remains as a
    // compatibility alias for the lock baseline.
    let spec = args.get_str("backend", &args.get_str("engine", "trust:8"));
    let backend = if spec == "stock" {
        BackendKind::Mutex
    } else {
        BackendKind::from_spec(&spec)
    };
    let server = McdServer::start(McdServerConfig {
        workers: args.get("workers", 4),
        dedicated: args.get("dedicated", 0),
        backend,
        budget_bytes: args.get::<u64>("budget-mb", 0) << 20,
        addr: args.get_str("addr", "127.0.0.1:11211"),
        net: parse_net(args),
        tuning: parse_tuning(args),
    });
    let prefill: u64 = args.get("prefill", 0);
    if prefill > 0 {
        server.prefill(prefill, args.get("val-len", 16));
        println!("prefilled {prefill} items");
    }
    println!(
        "mini-memcached listening on {} ({}) (ctrl-c to stop)",
        server.addr(),
        server.net_info().summary()
    );
    loop {
        std::thread::sleep(std::time::Duration::from_secs(3600));
    }
}

fn mcd_load(args: &Args) {
    let addr: std::net::SocketAddr = args
        .get_str("addr", "127.0.0.1:11211")
        .parse()
        .expect("bad --addr");
    let stats = run_memtier(&MemtierConfig {
        addr,
        threads: args.get("threads", 2),
        pipeline: args.get("pipeline", 48),
        ops_per_thread: args.get("ops", 10_000),
        keys: args.get("keys", 10_000),
        dist: args.get_str("dist", "uniform"),
        write_pct: args.get("write-pct", 5),
        ttl_pct: args.get("ttl-pct", 0),
        val_len: args.get("val-len", 16),
        seed: args.get("seed", 42),
        retry_shed: args.flag("retry-shed"),
    });
    bail_on_client_errors(&stats.errors);
    println!(
        "{} ops in {:.2}s = {} | hits {} misses {} shed {}",
        stats.ops,
        stats.elapsed.as_secs_f64(),
        fmt_mops(stats.throughput()),
        stats.hits,
        stats.misses,
        stats.shed
    );
}

fn resp_server(args: &Args) {
    let server = RespServer::start(RespServerConfig {
        workers: args.get("workers", 4),
        dedicated: args.get("dedicated", 0),
        backend: BackendKind::from_spec(&args.get_str("backend", "trust")),
        budget_bytes: args.get::<u64>("budget-mb", 0) << 20,
        addr: args.get_str("addr", "127.0.0.1:6379"),
        net: parse_net(args),
        tuning: parse_tuning(args),
    });
    let prefill: u64 = args.get("prefill", 0);
    if prefill > 0 {
        server.prefill(prefill, args.get("val-len", 16));
        println!("prefilled {prefill} keys");
    }
    println!(
        "resp (redis-protocol) server listening on {} ({}) (ctrl-c to stop)",
        server.addr(),
        server.net_info().summary()
    );
    loop {
        std::thread::sleep(std::time::Duration::from_secs(3600));
    }
}

fn resp_load(args: &Args) {
    let addr: std::net::SocketAddr = args
        .get_str("addr", "127.0.0.1:6379")
        .parse()
        .expect("bad --addr");
    let stats = run_resp_load(&RespLoadConfig {
        addr,
        threads: args.get("threads", 2),
        pipeline: args.get("pipeline", 32),
        ops_per_thread: args.get("ops", 10_000),
        keys: args.get("keys", 1_000),
        dist: args.get_str("dist", "uniform"),
        write_pct: args.get("write-pct", 5),
        ttl_pct: args.get("ttl-pct", 0),
        val_len: args.get("val-len", 16),
        seed: args.get("seed", 42),
        retry_shed: args.flag("retry-shed"),
    });
    bail_on_client_errors(&stats.errors);
    println!(
        "{} ops in {:.2}s = {} | hits {} misses {} shed {}",
        stats.ops,
        stats.elapsed.as_secs_f64(),
        fmt_mops(stats.throughput()),
        stats.hits,
        stats.misses,
        stats.shed
    );
}

fn fadd(args: &Args) {
    let engine = args.get_str("engine", "trust");
    let cfg = FaddConfig {
        threads: args.get("threads", 4),
        objects: args.get("objects", 64),
        ops_per_thread: args.get("ops", 20_000),
        dist: args.get_str("dist", "uniform"),
        seed: args.get("seed", 0xFADD),
        dedicated: args.get("dedicated", 0),
        fibers: args.get("fibers", 8),
        window: args.get("window", 64),
        flush: trustee::channel::FlushPolicy::from_spec(&args.get_str("flush", "adaptive")),
    };
    let r = match engine.as_str() {
        "trust" => run_trust(&cfg),
        "async" => run_async(&cfg),
        lock => run_lock_by_name(lock, &cfg),
    };
    println!("{engine}: {} ops in {:.3}s = {:.3} MOPs", r.ops, r.secs, r.mops());
}

fn demo() {
    let rt = trustee::runtime::Runtime::builder().workers(2).build();
    let v = rt.block_on(0, || {
        let ct = trustee::trust::local_trustee().entrust(17u64);
        ct.apply(|c| *c += 1);
        ct.apply(|c| *c)
    });
    println!("Figure 1: entrust(17); apply(+1) -> {v}");
    rt.shutdown();
}
