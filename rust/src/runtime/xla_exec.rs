//! PJRT executor: loads AOT-compiled HLO-text artifacts produced by
//! `python/compile/aot.py` and executes them from trustee threads.
//!
//! This is the L3↔L2 bridge of the three-layer stack: Python/JAX (+ the
//! Pallas batch-apply kernel) runs once at build time; at runtime the Rust
//! coordinator loads `artifacts/*.hlo.txt`, compiles it on the PJRT CPU
//! client, and executes it with concrete buffers. Python is never on the
//! request path.
//!
//! Interchange is HLO *text*, not a serialized `HloModuleProto`: jax ≥ 0.5
//! emits protos with 64-bit instruction ids that xla_extension 0.5.1
//! rejects; the text parser reassigns ids (see /opt/xla-example/README.md).

use anyhow::{Context, Result};
use std::path::Path;

/// A compiled XLA executable plus its client.
pub struct XlaExec {
    client: xla::PjRtClient,
    exe: xla::PjRtLoadedExecutable,
    name: String,
}

// SAFETY: the xla crate uses `Rc` and raw pointers internally, so its types
// are !Send, but the *object graph is self-contained*: `client` and `exe`
// hold the only Rc clones of the underlying PjRtClientInternal, and they
// move together as one XlaExec. Entrusting an XlaExec/BatchEngine moves the
// whole graph to the trustee thread, after which exactly one thread touches
// it at a time — the same discipline Trust<T> enforces for every property.
// (PJRT CPU itself is thread-safe; only the Rc refcounts require the
// single-owner argument.)
unsafe impl Send for XlaExec {}
// SAFETY: same single-owner argument as XlaExec above — a BatchEngine
// moves its whole self-contained Rc graph with it.
unsafe impl Send for BatchEngine {}

impl XlaExec {
    /// Load an HLO-text artifact and compile it for the CPU PJRT client.
    pub fn load(path: impl AsRef<Path>) -> Result<XlaExec> {
        let path = path.as_ref();
        let client = xla::PjRtClient::cpu().context("create PJRT CPU client")?;
        let proto = xla::HloModuleProto::from_text_file(path)
            .with_context(|| format!("parse HLO text {path:?}"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = client.compile(&comp).context("compile HLO")?;
        Ok(XlaExec {
            client,
            exe,
            name: path.file_name().map(|s| s.to_string_lossy().into_owned()).unwrap_or_default(),
        })
    }

    pub fn name(&self) -> &str {
        &self.name
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Execute with literal inputs; returns the elements of the result
    /// tuple (aot.py lowers with `return_tuple=True`).
    pub fn run(&self, inputs: &[xla::Literal]) -> Result<Vec<xla::Literal>> {
        let out = self.exe.execute::<xla::Literal>(inputs).context("execute")?;
        let result = out[0][0].to_literal_sync().context("fetch result")?;
        let tuple = result.to_tuple().context("untuple result")?;
        Ok(tuple)
    }
}

/// The trustee-side batched-apply engine: owns a counter-table shard as an
/// XLA literal and applies whole delegation batches through the compiled
/// `engine_step` artifact (L2+L1) in one executable call.
///
/// This is the accelerator-era extension of the paper's trustee loop: where
/// §5.2's trustee applies N closures sequentially, homogeneous batches
/// (fetch-and-add and friends) are applied as one kernel launch; the
/// returned `old` vector is the batch of responses.
pub struct BatchEngine {
    exec: XlaExec,
    table: xla::Literal,
    n: usize,
    batch: usize,
    /// Batches applied (metrics).
    pub batches: u64,
    /// Ops applied (metrics).
    pub ops: u64,
}

impl BatchEngine {
    /// `artifact` must be an `engine_step` lowering with static shapes
    /// (table=n, batch=b) — see `python/compile/model.py::AOT_VARIANTS`.
    pub fn new(artifact: impl AsRef<Path>, n: usize, batch: usize) -> Result<BatchEngine> {
        let exec = XlaExec::load(artifact)?;
        let table = xla::Literal::vec1(&vec![0i32; n]);
        Ok(BatchEngine { exec, table, n, batch, batches: 0, ops: 0 })
    }

    pub fn n(&self) -> usize {
        self.n
    }

    pub fn batch_size(&self) -> usize {
        self.batch
    }

    /// Apply one batch of (key, delta) ops; pads short batches with no-op
    /// (key 0, delta 0) entries. Returns the pre-increment values for the
    /// real ops, in submission order.
    pub fn apply_batch(&mut self, keys: &[i32], deltas: &[i32]) -> Result<Vec<i32>> {
        assert_eq!(keys.len(), deltas.len());
        assert!(keys.len() <= self.batch, "batch overflow");
        let real = keys.len();
        let mut k = keys.to_vec();
        let mut d = deltas.to_vec();
        k.resize(self.batch, 0);
        d.resize(self.batch, 0);
        let keys_l = xla::Literal::vec1(&k);
        let deltas_l = xla::Literal::vec1(&d);
        let table = std::mem::replace(&mut self.table, xla::Literal::vec1(&[0i32; 0]));
        let mut out = self.exec.run(&[table, keys_l, deltas_l])?;
        anyhow::ensure!(out.len() == 3, "engine_step returns (table, old, shard)");
        let old = out.remove(1).to_vec::<i32>()?;
        self.table = out.remove(0);
        self.batches += 1;
        self.ops += real as u64;
        Ok(old[..real].to_vec())
    }

    /// Read the full table back (diagnostics / tests).
    pub fn table(&self) -> Result<Vec<i32>> {
        Ok(self.table.to_vec::<i32>()?)
    }
}

#[cfg(test)]
mod tests {
    // Executable-level tests live in rust/tests/xla_artifacts.rs because
    // they need `make artifacts` to have produced the HLO files.
}
