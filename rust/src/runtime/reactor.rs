//! Per-worker readiness reactor: parks connection fibers on fd
//! readability/writability and wakes them from the scheduler's reactor
//! phase, so idle sockets cost O(ready fds) per tick instead of a
//! re-`read()` per connection per tick (DESIGN.md, "Network reactor").
//!
//! Each worker owns one `epoll` instance. Fibers call [`wait_fd`], which
//! registers their interest (`EPOLLONESHOT`, so a wake disarms the fd until
//! the next wait) and parks them via [`crate::fiber::suspend`]. The
//! scheduler polls the instance with a zero timeout every tick, and —
//! once a worker has been idle for a while — *blocks* in `epoll_wait` with
//! a bounded timeout instead of backoff-spinning. A per-worker `eventfd`
//! (written by [`super::Shared::inject`] and at shutdown) pops a blocked
//! worker out immediately; delegation batches arriving over the slot
//! matrix carry no fd signal, so the bounded timeout caps their added
//! latency at [`super::IDLE_EPOLL_TIMEOUT_MS`].
//!
//! Everything here is single-threaded per worker: the map from fd to
//! parked fiber is plain data, and a fiber parked on an fd can only be
//! woken by this reactor (or the shutdown sweep), never by a completion.

use crate::fiber::{self, FiberId};
use crate::util::sys;
use std::collections::HashMap;

/// `epoll_event.data` token reserved for the worker's wake `eventfd`.
const WAKE_TOKEN: u64 = u64::MAX;

/// Max events drained per `epoll_wait` call.
const EVENT_BATCH: usize = 64;

/// One worker's epoll instance plus its fd→fiber park table.
pub struct Reactor {
    epfd: sys::c_int,
    /// Wake eventfd (owned by [`super::Shared`]; registered here, not closed).
    wake_fd: sys::c_int,
    waiters: HashMap<i32, FiberId>,
}

impl Reactor {
    /// Build a reactor around a fresh epoll instance, registering the
    /// worker's wake eventfd. If `epoll_create1` fails the reactor is
    /// disabled and every [`wait_fd`] degrades to a fiber yield.
    pub(crate) fn new(wake_fd: i32) -> Reactor {
        // SAFETY: epoll_create1 has no memory preconditions; the fd is checked
        // before use.
        let epfd = unsafe { sys::epoll_create1(sys::EPOLL_CLOEXEC) };
        if epfd >= 0 && wake_fd >= 0 {
            let mut ev = sys::epoll_event { events: sys::EPOLLIN, data: WAKE_TOKEN };
            // SAFETY: epfd/wake_fd were checked valid; ev is a live epoll_event.
            unsafe { sys::epoll_ctl(epfd, sys::EPOLL_CTL_ADD, wake_fd, &mut ev) };
        }
        Reactor { epfd, wake_fd, waiters: HashMap::new() }
    }

    /// Is the epoll instance usable?
    pub fn enabled(&self) -> bool {
        self.epfd >= 0
    }

    /// Fibers currently parked on an fd.
    pub fn waiting(&self) -> usize {
        self.waiters.len()
    }

    /// Arm `fd` for one readiness event and record `fiber` as its waiter.
    /// Returns false (nothing recorded) if the interest could not be
    /// registered — the caller must not park the fiber in that case.
    pub(crate) fn register(
        &mut self,
        fd: i32,
        want_read: bool,
        want_write: bool,
        fiber: FiberId,
    ) -> bool {
        if self.epfd < 0 || (!want_read && !want_write) {
            return false;
        }
        let mut events = sys::EPOLLONESHOT;
        if want_read {
            events |= sys::EPOLLIN | sys::EPOLLRDHUP;
        }
        if want_write {
            events |= sys::EPOLLOUT;
        }
        let mut ev = sys::epoll_event { events, data: fd as u32 as u64 };
        // ADD for a fresh fd; an fd left registered (but disarmed) by a
        // previous oneshot wake fails ADD with EEXIST, so fall back to MOD.
        // SAFETY: ev is a live epoll_event; epfd is our epoll instance.
        let mut rc = unsafe { sys::epoll_ctl(self.epfd, sys::EPOLL_CTL_ADD, fd, &mut ev) };
        if rc < 0 {
            // SAFETY: same live arguments as the ADD attempt above.
            rc = unsafe { sys::epoll_ctl(self.epfd, sys::EPOLL_CTL_MOD, fd, &mut ev) };
        }
        if rc < 0 {
            return false;
        }
        self.waiters.insert(fd, fiber);
        true
    }

    /// Collect the fibers whose fds became ready into `out`, waiting up
    /// to `timeout_ms` (0 = non-blocking); returns the number appended.
    /// The scheduler passes its recycled scratch vector, so the steady
    /// network path allocates nothing per tick. Wake-eventfd events are
    /// drained here and produce no fiber.
    pub(crate) fn poll_into(&mut self, timeout_ms: i32, out: &mut Vec<FiberId>) -> usize {
        if self.epfd < 0 {
            return 0;
        }
        // Zero-timeout polls with nothing parked skip the syscall: the only
        // other registrant is the wake eventfd, whose payload (the injector
        // queue) is drained by the injector phase every tick anyway.
        if timeout_ms == 0 && self.waiters.is_empty() {
            return 0;
        }
        // Fault injection (`faults` feature only; inline no-op otherwise):
        // a simulated EINTR — the wait returns no events, exactly like the
        // real `n <= 0` path below, and the next tick retries. Parked
        // fibers stay parked; their fds stay armed.
        if crate::util::faultsim::epoll_fault() {
            return 0;
        }
        let mut events = [sys::epoll_event { events: 0, data: 0 }; EVENT_BATCH];
        // SAFETY: events is a live buffer of EVENT_BATCH entries and the
        // kernel writes at most that many.
        let n = unsafe {
            sys::epoll_wait(self.epfd, events.as_mut_ptr(), EVENT_BATCH as sys::c_int, timeout_ms)
        };
        if n <= 0 {
            return 0;
        }
        let before = out.len();
        for ev in &events[..n as usize] {
            let data = ev.data; // copy out of the packed struct
            if data == WAKE_TOKEN {
                self.drain_wake();
                continue;
            }
            if let Some(fiber) = self.waiters.remove(&(data as i32)) {
                out.push(fiber);
            }
        }
        out.len() - before
    }

    /// [`Reactor::poll_into`] with a fresh vector (tests/diagnostics; the
    /// scheduler uses the scratch-recycling form).
    #[cfg(test)]
    pub(crate) fn poll(&mut self, timeout_ms: i32) -> Vec<FiberId> {
        let mut out = Vec::new();
        self.poll_into(timeout_ms, &mut out);
        out
    }

    /// Detach every parked waiter into `out` (the shutdown sweep: fibers
    /// re-check their exit conditions once resumed).
    pub(crate) fn take_all_waiters_into(&mut self, out: &mut Vec<FiberId>) {
        out.extend(self.waiters.drain().map(|(_, f)| f));
    }

    fn drain_wake(&mut self) {
        if self.wake_fd >= 0 {
            let mut val: u64 = 0;
            // A single read resets the eventfd counter to zero.
            // SAFETY: wake_fd checked valid; val is a live writable u64.
            unsafe { sys::read(self.wake_fd, &mut val as *mut u64 as *mut sys::c_void, 8) };
        }
    }
}

impl Drop for Reactor {
    fn drop(&mut self) {
        if self.epfd >= 0 {
            // SAFETY: the Reactor owns epfd; closed exactly once, here.
            unsafe { sys::close(self.epfd) };
        }
    }
}

/// Park the current fiber until `fd` is readable (`want_read`) and/or
/// writable (`want_write`), the peer hangs up, or the runtime begins
/// shutdown.
///
/// Must be called from a fiber on a runtime worker. Spurious wake-ups are
/// possible (shutdown sweep, registration fallback): callers must re-check
/// their socket and loop. During shutdown — or with no interest at all —
/// this degrades to a yield so fibers keep draining instead of parking
/// forever.
pub fn wait_fd(fd: i32, want_read: bool, want_write: bool) {
    let shutting_down = super::with_worker(|w| w.shared.shutting_down());
    if shutting_down || (!want_read && !want_write) {
        fiber::yield_now();
        return;
    }
    fiber::suspend(|id| {
        let ok = super::with_worker(|w| w.reactor.register(fd, want_read, want_write, id));
        if !ok {
            // Could not arm the fd: make ourselves runnable again before
            // the switch-out so the park is only momentary (busy-poll
            // degradation, never a stranded fiber).
            fiber::with_executor(|e| {
                e.resume(id);
            });
        }
    });
}

/// Number of fd-parked fibers on the current worker (tests/metrics).
pub fn fd_waiters() -> usize {
    super::with_worker(|w| w.reactor.waiting())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn disabled_reactor_is_inert() {
        // A reactor built around an invalid wake fd must still behave.
        let mut r = Reactor { epfd: -1, wake_fd: -1, waiters: HashMap::new() };
        assert!(!r.enabled());
        assert!(!r.register(0, true, false, 0));
        assert!(r.poll(0).is_empty());
        let mut swept = Vec::new();
        r.take_all_waiters_into(&mut swept);
        assert!(swept.is_empty());
    }

    #[test]
    fn register_poll_wakes_on_readable() {
        use std::io::Write;
        use std::os::unix::io::AsRawFd;
        let listener = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let mut client = std::net::TcpStream::connect(addr).unwrap();
        let (server, _) = listener.accept().unwrap();

        let mut r = Reactor::new(-1);
        assert!(r.enabled());
        let fd = server.as_raw_fd();
        assert!(r.register(fd, true, false, 7));
        assert_eq!(r.waiting(), 1);
        assert!(r.poll(0).is_empty(), "no data yet");

        client.write_all(b"x").unwrap();
        client.flush().unwrap();
        let ready = r.poll(1000);
        assert_eq!(ready, vec![7]);
        assert_eq!(r.waiting(), 0);

        // Re-arming the same fd goes through the MOD fallback.
        assert!(r.register(fd, false, true, 9));
        let ready = r.poll(1000); // writable immediately
        assert_eq!(ready, vec![9]);
    }

    #[test]
    fn wake_eventfd_pops_a_blocking_poll() {
        // SAFETY: eventfd has no memory preconditions; checked below.
        let efd = unsafe { sys::eventfd(0, sys::EFD_NONBLOCK | sys::EFD_CLOEXEC) };
        assert!(efd >= 0);
        let mut r = Reactor::new(efd);
        let one: u64 = 1;
        // SAFETY: efd is the valid eventfd created above; one is a live u64.
        unsafe { sys::write(efd, &one as *const u64 as *const sys::c_void, 8) };
        // The wake event is swallowed (no fiber) but ends the wait early.
        let t0 = std::time::Instant::now();
        let ready = r.poll(2000);
        assert!(ready.is_empty());
        assert!(t0.elapsed() < std::time::Duration::from_millis(1500));
        // Counter was drained: the next zero-timeout poll is quiet. A
        // waiter must be parked or the syscall is skipped entirely, so
        // register a dummy pipe-less fd via a socketpair stand-in.
        let listener = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let _client = std::net::TcpStream::connect(addr).unwrap();
        let (server, _) = listener.accept().unwrap();
        use std::os::unix::io::AsRawFd;
        assert!(r.register(server.as_raw_fd(), true, false, 1));
        assert!(r.poll(0).is_empty());
        // SAFETY: efd was created by this test; closed exactly once.
        unsafe { sys::close(efd) };
    }
}
