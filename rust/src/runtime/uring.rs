//! Per-worker io_uring reactor: the batched-kernel-boundary sibling of
//! the epoll [`super::reactor`].
//!
//! The epoll reactor already made *idle* connections cheap, but every
//! park still pays an `epoll_ctl` syscall to re-arm its oneshot interest,
//! and every tick with waiters pays an `epoll_wait`. This reactor applies
//! the crate's delegation philosophy — batch many requests onto one
//! carrier — to the kernel boundary itself: fibers that park on fd
//! readiness *stage* a `POLL_ADD` SQE into the worker's mmap'd submission
//! ring (a few plain stores, no syscall), and the scheduler publishes the
//! whole batch with **one `io_uring_enter` per loop** from its flush
//! phase, mirroring the outbox flush-watermark discipline. Completions
//! are harvested from the mmap'd completion ring with **no syscall at
//! all**. The listener uses a single multishot `ACCEPT` SQE, so a wave of
//! new connections costs one staged SQE total, and each worker's wake
//! eventfd is armed with a multishot `POLL_ADD` so [`super::Shared::inject`]
//! and shutdown still pop a blocked `io_uring_enter` wait instantly.
//!
//! ## Ring memory-ordering contract
//!
//! The SQ/CQ rings are shared memory between this thread and the kernel
//! (DESIGN.md, "Kernel-boundary batching"):
//!
//! - **SQ (we produce, kernel consumes):** write the SQE body and the
//!   `array[idx]` slot with plain stores, then publish by storing the SQ
//!   tail with `Release`; read the kernel's SQ head with `Acquire` for
//!   the ring-full check.
//! - **CQ (kernel produces, we consume):** read the CQ tail with
//!   `Acquire`, copy CQEs out by value, then store the CQ head with
//!   `Release` so the kernel may reuse the entries.
//!
//! ## Two planes: readiness and data
//!
//! The reactor runs one of two planes per connection (DESIGN.md,
//! "Kernel-boundary batching"):
//!
//! - **Readiness plane** (always available): `POLL_ADD` parks as above;
//!   payload bytes move through the engine's ordinary non-blocking
//!   `read`/`write` calls once a fiber is woken.
//! - **Data plane** (kernels with `IORING_REGISTER_PBUF_RING`): the
//!   worker registers a provided-buffer ring ([`PbufRing`]) and each
//!   connection arms one **multishot `RECV`** SQE with
//!   `IOSQE_BUFFER_SELECT` — arriving bytes land in kernel-picked pool
//!   buffers and surface as CQEs with **no `read` syscall**. Responses
//!   go out as ring-submitted `SEND` SQEs (short writes continue with a
//!   follow-up SQE). Idle connections hold no committed inbuf.
//!
//! ## Buffer-ownership contract (data plane)
//!
//! A pool buffer is **kernel-owned** from the moment it is published at
//! the buf_ring tail until a RECV CQE names its `bid`; it is then
//! **engine-owned** (the connection fiber parses it, in place when a
//! whole frame landed) until [`UringReactor::recv_recycle`] republishes
//! it. Backpressure is *withheld replenishment*: a fiber over its
//! `MAX_INBUF` backlog stops taking/recycling, the pool drains, and the
//! kernel's `ENOBUFS` (counted, re-armed on the next recycle) stops the
//! flow without a syscall per stall. SEND SQEs reference only
//! reactor-owned buffers ([`ConnState::send_active`], frozen while an
//! SQE is in flight), never fiber stack memory, and a closing
//! connection's slot is finalized only after its last SEND CQE lands.
//!
//! ## SQE lifetime / user_data
//!
//! `POLL_ADD` and `ACCEPT` (with null address buffers) carry **no
//! userspace buffer**; `RECV` borrows kernel-selected pool buffers and
//! `SEND` borrows the frozen `send_active` vector per the contract
//! above. `user_data` carries a kind tag in the top byte and the payload
//! ([`FiberId`], accept token, or generation-tagged connection token)
//! below it; a parked fiber is woken only while it is present in the
//! `waiters` set, and connection CQEs are dropped (their buffers
//! recycled) when the slot generation no longer matches, so a stale CQE
//! never wakes an unrelated fiber or corrupts a recycled slot. Wake-ups
//! may still be spurious — every waiter re-checks its socket/queues.

use crate::fiber::{self, FiberId};
use crate::util::sys;
use std::collections::{HashSet, VecDeque};
use std::sync::atomic::{AtomicBool, AtomicU16, AtomicU32, Ordering};
use std::sync::OnceLock;

/// SQ entries per worker ring (CQ gets 2x). Bounds SQEs *staged per
/// scheduler loop*, not total parked fibers (the kernel holds armed polls
/// internally after submission); an overfull loop flushes mid-stage and
/// counts it in [`UringStats::sq_full_flushes`].
const URING_ENTRIES: u32 = 4096;

/// `user_data` layout: kind tag in the top byte, payload below.
const UD_KIND_SHIFT: u32 = 56;
const UD_PAYLOAD_MASK: u64 = (1u64 << UD_KIND_SHIFT) - 1;
const KIND_POLL: u64 = 1;
const KIND_ACCEPT: u64 = 2;
const KIND_WAKE: u64 = 3;
const KIND_RECV: u64 = 4;
const KIND_SEND: u64 = 5;

/// Connection-op payload layout: slot index in the low bits, slot
/// generation above it. A recycled slot bumps its generation, so a late
/// CQE from the slot's previous life fails the generation check and is
/// dropped (its provided buffer recycled) instead of corrupting the new
/// occupant.
const CONN_TOKEN_BITS: u32 = 24;
const CONN_TOKEN_MASK: u64 = (1u64 << CONN_TOKEN_BITS) - 1;

fn conn_ud(kind: u64, gen: u32, token: usize) -> u64 {
    debug_assert!((token as u64) <= CONN_TOKEN_MASK);
    (kind << UD_KIND_SHIFT) | ((gen as u64) << CONN_TOKEN_BITS) | (token as u64 & CONN_TOKEN_MASK)
}

fn conn_ud_split(payload: u64) -> (u32, usize) {
    (((payload >> CONN_TOKEN_BITS) & u32::MAX as u64) as u32, (payload & CONN_TOKEN_MASK) as usize)
}

/// Provided-buffer ring geometry per worker: `PBUF_ENTRIES` buffers of
/// `PBUF_BUF_SZ` bytes each (4 MiB total). One buffer group per ring.
const PBUF_ENTRIES: u16 = 256;
const PBUF_BUF_SZ: usize = 16 * 1024;
const PBUF_BGID: u16 = 0;

/// `-ENOBUFS`: the kernel found the provided-buffer pool empty.
const ENOBUFS_ERR: i32 = 105;

/// Submission/completion counters (metrics + the batching contract:
/// `enters` grows by at most one per scheduler loop regardless of how
/// many connections had pending I/O).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct UringStats {
    /// `io_uring_enter` syscalls issued (submission flushes + blocking
    /// waits).
    pub enters: u64,
    /// SQEs submitted across all enters.
    pub sqes_submitted: u64,
    /// CQEs harvested from the completion ring.
    pub cqes_harvested: u64,
    /// Mid-loop flushes forced by a full SQ ring (should be ~0).
    pub sq_full_flushes: u64,
    /// Enters that blocked waiting for a completion (idle phase).
    pub enter_waits: u64,
    /// Largest SQE batch a single enter carried.
    pub max_sqes_per_enter: u64,
    /// Data-plane RECV completions (each delivered a provided buffer,
    /// an EOF, or a pool-exhaustion notice) — `> 0` proves the data
    /// plane actually ran.
    pub recv_cqes: u64,
    /// Provided buffers returned to the pool after the engine consumed
    /// them (steady state: ≈ buffers consumed; a widening gap is a leak).
    pub pbuf_recycled: u64,
    /// RECV completions that found the pool empty (`-ENOBUFS`):
    /// backpressure-by-withheld-replenishment engaging at the wire.
    pub enobufs: u64,
    /// Data-plane SEND SQEs staged.
    pub send_sqes: u64,
    /// Follow-up SEND SQEs staged because a completion wrote short.
    pub short_send_continuations: u64,
}

impl UringStats {
    pub fn merge(&mut self, o: &UringStats) {
        self.enters += o.enters;
        self.sqes_submitted += o.sqes_submitted;
        self.cqes_harvested += o.cqes_harvested;
        self.sq_full_flushes += o.sq_full_flushes;
        self.enter_waits += o.enter_waits;
        self.max_sqes_per_enter = self.max_sqes_per_enter.max(o.max_sqes_per_enter);
        self.recv_cqes += o.recv_cqes;
        self.pbuf_recycled += o.pbuf_recycled;
        self.enobufs += o.enobufs;
        self.send_sqes += o.send_sqes;
        self.short_send_continuations += o.short_send_continuations;
    }
}

/// One multishot-accept registration (one per listener; in practice one
/// per server).
struct AcceptState {
    listener_fd: i32,
    /// Accepted connection fds delivered by CQEs, awaiting the acceptor
    /// fiber.
    queue: VecDeque<i32>,
    /// The acceptor fiber, when parked waiting for the next connection.
    parked: Option<FiberId>,
    /// Is the multishot SQE still armed in the kernel? (A CQE without
    /// `IORING_CQE_F_MORE` disarms it; `accept_take` re-arms.)
    armed: bool,
    closed: bool,
}

/// The worker's registered provided-buffer ring: a shared
/// `io_uring_buf` array the kernel pops buffers from (head, kernel-side)
/// and we republish consumed buffers to (tail, published with `Release`
/// through entry 0's `resv` word — the kernel's
/// `io_uring_buf_ring.tail` union member), plus the buffer slab itself.
struct PbufRing {
    ring_ptr: *mut sys::io_uring_buf,
    ring_len: usize,
    slab_ptr: *mut u8,
    slab_len: usize,
    entries: u16,
    mask: u16,
    /// Local tail mirror; the shared tail word is store-only from our
    /// side (the kernel never writes it).
    tail_local: u16,
}

impl PbufRing {
    /// Map the buf_ring + slab and register the ring with the kernel.
    fn new(ring_fd: i32) -> Result<PbufRing, String> {
        let entries = PBUF_ENTRIES;
        let ring_len = entries as usize * std::mem::size_of::<sys::io_uring_buf>();
        // SAFETY: fresh anonymous mapping; checked against MAP_FAILED
        // before use.
        let ring_ptr = unsafe {
            sys::mmap(
                std::ptr::null_mut(),
                ring_len,
                sys::PROT_READ | sys::PROT_WRITE,
                sys::MAP_PRIVATE | sys::MAP_ANONYMOUS,
                -1,
                0,
            )
        };
        if ring_ptr == sys::MAP_FAILED {
            return Err(format!("pbuf ring mmap: {}", std::io::Error::last_os_error()));
        }
        let reg = sys::io_uring_buf_reg {
            ring_addr: ring_ptr as u64,
            ring_entries: entries as u32,
            bgid: PBUF_BGID,
            flags: 0,
            resv: [0; 3],
        };
        // SAFETY: ring_fd is a live io_uring fd; reg is a live
        // io_uring_buf_reg naming the mapping created above (nr_args = 1
        // per the PBUF_RING register contract).
        let rc = unsafe {
            sys::io_uring_register(
                ring_fd,
                sys::IORING_REGISTER_PBUF_RING,
                &reg as *const sys::io_uring_buf_reg as *const sys::c_void,
                1,
            )
        };
        if rc < 0 {
            let e = std::io::Error::last_os_error();
            // SAFETY: ring_ptr is the live mapping created above; unmapped
            // exactly once on this early-exit path.
            unsafe { sys::munmap(ring_ptr, ring_len) };
            return Err(format!("IORING_REGISTER_PBUF_RING: {e} (kernel lacks pbuf rings?)"));
        }
        let slab_len = entries as usize * PBUF_BUF_SZ;
        // SAFETY: fresh anonymous mapping; checked against MAP_FAILED.
        let slab_ptr = unsafe {
            sys::mmap(
                std::ptr::null_mut(),
                slab_len,
                sys::PROT_READ | sys::PROT_WRITE,
                sys::MAP_PRIVATE | sys::MAP_ANONYMOUS,
                -1,
                0,
            )
        };
        if slab_ptr == sys::MAP_FAILED {
            let e = std::io::Error::last_os_error();
            // SAFETY: unregister the ring we just registered and release
            // its mapping, each exactly once on this early-exit path.
            unsafe {
                sys::io_uring_register(
                    ring_fd,
                    sys::IORING_UNREGISTER_PBUF_RING,
                    &reg as *const sys::io_uring_buf_reg as *const sys::c_void,
                    1,
                );
                sys::munmap(ring_ptr, ring_len);
            }
            return Err(format!("pbuf slab mmap: {e}"));
        }
        let mut p = PbufRing {
            ring_ptr: ring_ptr as *mut sys::io_uring_buf,
            ring_len,
            slab_ptr: slab_ptr as *mut u8,
            slab_len,
            entries,
            mask: entries - 1,
            tail_local: 0,
        };
        // Hand the whole pool to the kernel up front.
        for bid in 0..entries {
            p.provide(bid);
        }
        Ok(p)
    }

    /// The shared ring tail: entry 0's `resv` halfword (the kernel's
    /// `io_uring_buf_ring.tail` union member).
    fn tail_word(&self) -> *const AtomicU16 {
        // SAFETY: ring_ptr is the live ring mapping; the resv field of
        // entry 0 is 2-byte aligned (offset 14 of a 16-byte struct), so
        // the AtomicU16 cast is sound. The kernel only reads this word.
        unsafe { std::ptr::addr_of!((*self.ring_ptr).resv) as *const AtomicU16 }
    }

    /// Publish buffer `bid` at the ring tail (ownership returns to the
    /// kernel the instant the tail store lands).
    fn provide(&mut self, bid: u16) {
        debug_assert!(bid < self.entries);
        let idx = (self.tail_local & self.mask) as usize;
        // SAFETY: idx < entries keeps the entry write inside the ring
        // mapping and bid < entries keeps the address inside the slab;
        // the slot below the unpublished tail is ours exclusively. The
        // `resv` field is deliberately left untouched — in entry 0 it is
        // the shared tail word.
        unsafe {
            let e = self.ring_ptr.add(idx);
            (*e).addr = self.slab_ptr.add(bid as usize * PBUF_BUF_SZ) as u64;
            (*e).len = PBUF_BUF_SZ as u32;
            (*e).bid = bid;
        }
        self.tail_local = self.tail_local.wrapping_add(1);
        // SAFETY: tail_word points into the live mapping; the Release
        // store publishes the entry writes above to the kernel's Acquire.
        unsafe { (*self.tail_word()).store(self.tail_local, Ordering::Release) };
    }

    /// Start of buffer `bid` in the slab (valid for `PBUF_BUF_SZ` bytes).
    fn buf_ptr(&self, bid: u16) -> *const u8 {
        debug_assert!(bid < self.entries);
        // SAFETY: bid < entries keeps the pointer inside the slab mapping.
        unsafe { self.slab_ptr.add(bid as usize * PBUF_BUF_SZ) }
    }
}

/// One RECV completion's worth of bytes awaiting the connection fiber.
/// `owns` is false only for the front half of a fault-split segment —
/// the buffer goes back to the pool once the owning half is consumed.
struct RecvSeg {
    bid: u16,
    off: u32,
    len: u32,
    owns: bool,
}

/// Per-connection data-plane state (multishot RECV + ring-batched SEND).
struct ConnState {
    fd: i32,
    gen: u32,
    /// Kernel-filled segments the fiber has not yet taken. Withholding
    /// takes (and hence recycles) is the backpressure mechanism.
    queue: VecDeque<RecvSeg>,
    parked: Option<FiberId>,
    /// Is the multishot RECV SQE still armed in the kernel?
    recv_armed: bool,
    eof: bool,
    recv_err: Option<i32>,
    /// Hit `-ENOBUFS`; re-armed from `recv_recycle` (not at park time)
    /// so an empty pool cannot spin arm→ENOBUFS→arm.
    starved: bool,
    /// Bytes an in-flight (or about-to-be-staged) SEND SQE references.
    /// **Frozen** (never mutated, never reallocated) while
    /// `send_inflight` — the kernel reads it concurrently.
    send_active: Vec<u8>,
    /// Bytes of `send_active` already acknowledged by SEND CQEs.
    send_acked: usize,
    send_inflight: bool,
    /// Overflow bytes queued while a SEND was in flight; swapped into
    /// `send_active` when it settles.
    send_next: Vec<u8>,
    send_err: bool,
    /// Fiber has detached; finalize the slot (close fd, recycle queued
    /// buffers) once the in-flight SEND settles.
    closing: bool,
}

impl ConnState {
    fn send_pending(&self) -> usize {
        (self.send_active.len() - self.send_acked) + self.send_next.len()
    }
}

/// What [`UringReactor::recv_take`] hands the connection fiber.
pub(crate) enum RecvTake {
    /// One kernel-filled segment, engine-owned until recycled. `ptr` is
    /// valid for `len` bytes until `recv_recycle(bid, owns)` runs.
    Data { ptr: *const u8, len: u32, bid: u16, owns: bool },
    /// Nothing queued (RECV re-armed if the pool allows) — park.
    Empty,
    Eof,
    Err(i32),
}

/// One worker's io_uring instance: ring mappings, staged-submission
/// state, the parked-fiber set, and accept registrations.
pub struct UringReactor {
    ring_fd: i32,
    /// Wake eventfd (owned by [`super::Shared`]; armed here, not closed).
    wake_fd: i32,
    /// The (single) ring mapping and the SQE array mapping.
    ring_ptr: *mut u8,
    ring_len: usize,
    sqes_ptr: *mut sys::io_uring_sqe,
    sqes_len: usize,
    // SQ geometry/pointers (into ring_ptr).
    sq_head: *const AtomicU32,
    sq_tail: *const AtomicU32,
    sq_flags: *const AtomicU32,
    sq_mask: u32,
    sq_entries: u32,
    sq_array: *mut u32,
    // CQ geometry/pointers (into ring_ptr).
    cq_head: *const AtomicU32,
    cq_tail: *const AtomicU32,
    cq_mask: u32,
    cqes: *const sys::io_uring_cqe,
    /// Local (unpublished) SQ tail and the value last published+entered.
    sq_tail_local: u32,
    sq_tail_submitted: u32,
    /// Fibers parked on a POLL_ADD; a CQE wakes a fiber only while its id
    /// is in here (stale CQEs are ignored).
    waiters: HashSet<FiberId>,
    /// Is the wake eventfd's multishot poll currently armed?
    wake_armed: bool,
    accepts: Vec<Option<AcceptState>>,
    /// Data-plane connection slots; `gen` survives slot reuse so stale
    /// CQEs are detectable.
    conns: Vec<ConnSlot>,
    free_conns: Vec<usize>,
    /// Tokens whose RECV hit `-ENOBUFS`, re-armed one per recycle.
    starved: VecDeque<usize>,
    /// The provided-buffer ring, created lazily on the first
    /// data-plane registration; `pbuf_disabled` latches a failure so an
    /// incapable kernel pays the probe once.
    pbuf: Option<PbufRing>,
    pbuf_disabled: bool,
    pub stats: UringStats,
}

struct ConnSlot {
    gen: u32,
    state: Option<ConnState>,
}

/// Probe io_uring availability once per process: ring creation, the
/// feature bits the reactor depends on, and the ring mappings. Servers
/// resolve `NetPolicy::IoUring` through this and fall back to epoll
/// (with the returned reason) when it fails.
pub fn probe() -> Result<(), String> {
    static PROBE: OnceLock<Result<(), String>> = OnceLock::new();
    PROBE
        .get_or_init(|| UringReactor::new_with_entries(-1, 8).map(drop))
        .clone()
}

/// Probe the *data plane* once per process: ring creation plus a
/// provided-buffer ring registration (`IORING_REGISTER_PBUF_RING`).
/// Pure kernel capability — the runtime kill switch
/// (`TRUSTEE_URING_NO_PBUF` / [`set_dataplane_enabled`]) is separate,
/// so a bench can A/B the two planes inside one process.
pub fn probe_pbuf() -> Result<(), String> {
    static PROBE: OnceLock<Result<(), String>> = OnceLock::new();
    PROBE
        .get_or_init(|| {
            let r = UringReactor::new_with_entries(-1, 8)?;
            let p = PbufRing::new(r.ring_fd)?;
            drop(r); // closes the ring fd, which tears down the registration
            // SAFETY: the probe owns these two fresh mappings; each is
            // released exactly once, after the ring fd close above.
            unsafe {
                sys::munmap(p.ring_ptr as *mut sys::c_void, p.ring_len);
                sys::munmap(p.slab_ptr as *mut sys::c_void, p.slab_len);
            }
            Ok(())
        })
        .clone()
}

/// Runtime kill switch for the data plane (initialized from
/// `TRUSTEE_URING_NO_PBUF`): when off, `NetPolicy::IoUring` keeps the
/// readiness plane even on pbuf-capable kernels. Consulted at each
/// reactor's first data-plane registration, so flipping it between
/// server starts (as the A/B benches do) takes effect per server.
pub fn dataplane_enabled() -> bool {
    dataplane_flag().load(Ordering::Relaxed)
}

/// Flip the data-plane kill switch (benches/tests; servers started
/// after the flip observe it).
pub fn set_dataplane_enabled(on: bool) {
    dataplane_flag().store(on, Ordering::Relaxed);
}

fn dataplane_flag() -> &'static AtomicBool {
    static FLAG: OnceLock<AtomicBool> = OnceLock::new();
    FLAG.get_or_init(|| AtomicBool::new(std::env::var_os("TRUSTEE_URING_NO_PBUF").is_none()))
}

impl UringReactor {
    /// Build a reactor around a fresh ring, arming the worker's wake
    /// eventfd (when valid) with a multishot poll so cross-worker wakes
    /// end a blocking [`UringReactor::enter_wait`] instantly.
    pub(crate) fn new(wake_fd: i32) -> Result<Box<UringReactor>, String> {
        Self::new_with_entries(wake_fd, URING_ENTRIES)
    }

    fn new_with_entries(wake_fd: i32, entries: u32) -> Result<Box<UringReactor>, String> {
        let mut p = sys::io_uring_params::default();
        // SAFETY: p is a live zeroed params block; the fd is checked below.
        let ring_fd = unsafe { sys::io_uring_setup(entries, &mut p) };
        if ring_fd < 0 {
            return Err(format!("io_uring_setup: {}", std::io::Error::last_os_error()));
        }
        // Close the fd on any early return below.
        struct FdGuard(i32);
        impl Drop for FdGuard {
            fn drop(&mut self) {
                if self.0 >= 0 {
                    // SAFETY: the guard owns the fd; closed exactly once.
                    unsafe { sys::close(self.0) };
                }
            }
        }
        let mut guard = FdGuard(ring_fd);
        let need =
            sys::IORING_FEAT_SINGLE_MMAP | sys::IORING_FEAT_NODROP | sys::IORING_FEAT_EXT_ARG;
        if p.features & need != need {
            return Err(format!(
                "io_uring features {:#x} lack required SINGLE_MMAP|NODROP|EXT_ARG (kernel too old)",
                p.features
            ));
        }
        let sq_sz = p.sq_off.array as usize + p.sq_entries as usize * std::mem::size_of::<u32>();
        let cq_sz = p.cq_off.cqes as usize
            + p.cq_entries as usize * std::mem::size_of::<sys::io_uring_cqe>();
        let ring_len = sq_sz.max(cq_sz);
        // SAFETY: mapping the just-created ring fd at the documented offset;
        // checked against MAP_FAILED before use.
        let ring_ptr = unsafe {
            sys::mmap(
                std::ptr::null_mut(),
                ring_len,
                sys::PROT_READ | sys::PROT_WRITE,
                sys::MAP_SHARED | sys::MAP_POPULATE,
                ring_fd,
                sys::IORING_OFF_SQ_RING,
            )
        };
        if ring_ptr == sys::MAP_FAILED {
            return Err(format!("io_uring ring mmap: {}", std::io::Error::last_os_error()));
        }
        let sqes_len = p.sq_entries as usize * std::mem::size_of::<sys::io_uring_sqe>();
        // SAFETY: as above, at the SQE-array offset; checked before use. On
        // failure the ring mapping is released before returning.
        let sqes_ptr = unsafe {
            sys::mmap(
                std::ptr::null_mut(),
                sqes_len,
                sys::PROT_READ | sys::PROT_WRITE,
                sys::MAP_SHARED | sys::MAP_POPULATE,
                ring_fd,
                sys::IORING_OFF_SQES,
            )
        };
        if sqes_ptr == sys::MAP_FAILED {
            let e = std::io::Error::last_os_error();
            // SAFETY: ring_ptr is the live mapping created above; unmapped
            // exactly once on this early-exit path.
            unsafe { sys::munmap(ring_ptr, ring_len) };
            return Err(format!("io_uring sqes mmap: {e}"));
        }
        let base = ring_ptr as *mut u8;
        // SAFETY: all offsets come from the kernel's params block and lie
        // within the mapping; the kernel guarantees natural alignment, so
        // casting the u32 head/tail/flags words to AtomicU32 is sound.
        let (sq_head, sq_tail, sq_flags, sq_mask, sq_entries, sq_array, tail0) = unsafe {
            (
                base.add(p.sq_off.head as usize) as *const AtomicU32,
                base.add(p.sq_off.tail as usize) as *const AtomicU32,
                base.add(p.sq_off.flags as usize) as *const AtomicU32,
                *(base.add(p.sq_off.ring_mask as usize) as *const u32),
                *(base.add(p.sq_off.ring_entries as usize) as *const u32),
                base.add(p.sq_off.array as usize) as *mut u32,
                (*(base.add(p.sq_off.tail as usize) as *const AtomicU32)).load(Ordering::Acquire),
            )
        };
        // SAFETY: same justification as the SQ pointer derivations above.
        let (cq_head, cq_tail, cq_mask, cqes) = unsafe {
            (
                base.add(p.cq_off.head as usize) as *const AtomicU32,
                base.add(p.cq_off.tail as usize) as *const AtomicU32,
                *(base.add(p.cq_off.ring_mask as usize) as *const u32),
                base.add(p.cq_off.cqes as usize) as *const sys::io_uring_cqe,
            )
        };
        guard.0 = -1; // ownership moves into the reactor
        let mut r = Box::new(UringReactor {
            ring_fd,
            wake_fd,
            ring_ptr: ring_ptr as *mut u8,
            ring_len,
            sqes_ptr: sqes_ptr as *mut sys::io_uring_sqe,
            sqes_len,
            sq_head,
            sq_tail,
            sq_flags,
            sq_mask,
            sq_entries,
            sq_array,
            cq_head,
            cq_tail,
            cq_mask,
            cqes,
            sq_tail_local: tail0,
            sq_tail_submitted: tail0,
            waiters: HashSet::new(),
            wake_armed: false,
            accepts: Vec::new(),
            conns: Vec::new(),
            free_conns: Vec::new(),
            starved: VecDeque::new(),
            pbuf: None,
            pbuf_disabled: false,
            stats: UringStats::default(),
        });
        if wake_fd >= 0 {
            r.arm_wake();
            r.flush();
        }
        Ok(r)
    }

    /// Fibers currently parked on a poll SQE (incl. parked acceptors and
    /// data-plane connection fibers).
    pub fn waiting(&self) -> usize {
        self.waiters.len()
            + self.accepts.iter().flatten().filter(|a| a.parked.is_some()).count()
            + self
                .conns
                .iter()
                .filter_map(|s| s.state.as_ref())
                .filter(|c| c.parked.is_some())
                .count()
    }

    /// Should the idle scheduler block in this ring's `enter_wait` (vs
    /// the epoll reactor)? True while anything is parked here.
    pub fn wants_block(&self) -> bool {
        self.waiting() > 0
    }

    /// Stage one SQE, flushing mid-loop only if the ring is full. Returns
    /// a pointer valid until the next stage/flush.
    fn next_sqe(&mut self) -> Option<*mut sys::io_uring_sqe> {
        // SAFETY: sq_head points into the live ring mapping (kernel-written
        // consumer index).
        let head = unsafe { (*self.sq_head).load(Ordering::Acquire) };
        if self.sq_tail_local.wrapping_sub(head) >= self.sq_entries {
            // Ring full this loop: publish + enter now (counted; the
            // batching contract is "at most one enter per loop" in the
            // steady state, not a hard ceiling under pathological bursts).
            self.stats.sq_full_flushes += 1;
            self.flush();
            // SAFETY: as above.
            let head = unsafe { (*self.sq_head).load(Ordering::Acquire) };
            if self.sq_tail_local.wrapping_sub(head) >= self.sq_entries {
                return None;
            }
        }
        let idx = self.sq_tail_local & self.sq_mask;
        // SAFETY: idx < sq_entries, so both derived pointers stay inside
        // their mappings; the slot is ours exclusively until the tail that
        // covers it is published.
        let sqe = unsafe {
            let sqe = self.sqes_ptr.add(idx as usize);
            std::ptr::write(sqe, sys::io_uring_sqe::default());
            std::ptr::write(self.sq_array.add(idx as usize), idx);
            sqe
        };
        self.sq_tail_local = self.sq_tail_local.wrapping_add(1);
        Some(sqe)
    }

    /// Arm `fd` for one readiness event (oneshot `POLL_ADD`) and record
    /// `fiber` as its waiter. Returns false (nothing recorded) if no SQE
    /// could be staged — the caller must not park the fiber then.
    pub(crate) fn register(
        &mut self,
        fd: i32,
        want_read: bool,
        want_write: bool,
        fiber: FiberId,
    ) -> bool {
        if !want_read && !want_write {
            return false;
        }
        let mut mask = sys::POLLERR | sys::POLLHUP;
        if want_read {
            mask |= sys::POLLIN | sys::POLLRDHUP;
        }
        if want_write {
            mask |= sys::POLLOUT;
        }
        let Some(sqe) = self.next_sqe() else { return false };
        // SAFETY: sqe was just staged by next_sqe and is exclusively ours
        // until the tail publish.
        unsafe {
            (*sqe).opcode = sys::IORING_OP_POLL_ADD;
            (*sqe).fd = fd;
            (*sqe).op_flags = mask;
            (*sqe).user_data = (KIND_POLL << UD_KIND_SHIFT) | (fiber as u64 & UD_PAYLOAD_MASK);
        }
        self.waiters.insert(fiber);
        true
    }

    /// Stage the wake eventfd's multishot poll.
    fn arm_wake(&mut self) {
        if self.wake_fd < 0 || self.wake_armed {
            return;
        }
        if let Some(sqe) = self.next_sqe() {
            // SAFETY: sqe staged by next_sqe, exclusively ours until publish.
            unsafe {
                (*sqe).opcode = sys::IORING_OP_POLL_ADD;
                (*sqe).fd = self.wake_fd;
                (*sqe).op_flags = sys::POLLIN;
                (*sqe).len = sys::IORING_POLL_ADD_MULTI;
                (*sqe).user_data = KIND_WAKE << UD_KIND_SHIFT;
            }
            self.wake_armed = true;
        }
    }

    /// Register a listener for multishot accept; returns the token the
    /// acceptor fiber polls with [`UringReactor::accept_take`].
    pub(crate) fn accept_register(&mut self, listener_fd: i32) -> Option<usize> {
        let token = match self.accepts.iter().position(|a| a.is_none()) {
            Some(i) => i,
            None => {
                self.accepts.push(None);
                self.accepts.len() - 1
            }
        };
        self.accepts[token] = Some(AcceptState {
            listener_fd,
            queue: VecDeque::new(),
            parked: None,
            armed: false,
            closed: false,
        });
        if !self.arm_accept(token) {
            self.accepts[token] = None;
            return None;
        }
        Some(token)
    }

    fn arm_accept(&mut self, token: usize) -> bool {
        let fd = match &self.accepts[token] {
            Some(a) if !a.closed && !a.armed => a.listener_fd,
            _ => return self.accepts[token].as_ref().is_some_and(|a| a.armed),
        };
        let Some(sqe) = self.next_sqe() else { return false };
        // SAFETY: sqe staged by next_sqe, exclusively ours until publish.
        // addr/off stay null: we do not ask for the peer address, so the
        // SQE references no userspace memory while in flight.
        unsafe {
            (*sqe).opcode = sys::IORING_OP_ACCEPT;
            (*sqe).fd = fd;
            (*sqe).ioprio = sys::IORING_ACCEPT_MULTISHOT;
            (*sqe).op_flags = sys::SOCK_CLOEXEC;
            (*sqe).user_data = (KIND_ACCEPT << UD_KIND_SHIFT) | token as u64;
        }
        if let Some(a) = &mut self.accepts[token] {
            a.armed = true;
        }
        true
    }

    /// Pop the next accepted connection fd, re-arming the multishot SQE
    /// if the kernel disarmed it (e.g. after EMFILE). `None` means
    /// "nothing pending — park".
    pub(crate) fn accept_take(&mut self, token: usize) -> Option<i32> {
        let needs_arm = match &self.accepts[token] {
            Some(a) if !a.closed => a.queue.is_empty() && !a.armed,
            _ => false,
        };
        if needs_arm {
            self.arm_accept(token);
        }
        self.accepts[token].as_mut().and_then(|a| a.queue.pop_front())
    }

    /// Park `fiber` until a connection lands on `token`. False if the
    /// fiber must not park (work already queued, or the slot is closed).
    pub(crate) fn accept_park(&mut self, token: usize, fiber: FiberId) -> bool {
        match &mut self.accepts[token] {
            Some(a) if !a.closed && a.queue.is_empty() => {
                a.parked = Some(fiber);
                true
            }
            _ => false,
        }
    }

    /// Tear down an accept registration, closing any queued-but-untaken
    /// connection fds. Late CQEs for the token are closed on arrival.
    pub(crate) fn accept_close(&mut self, token: usize) {
        if let Some(a) = &mut self.accepts[token] {
            a.closed = true;
            while let Some(fd) = a.queue.pop_front() {
                // SAFETY: fd was delivered by an accept CQE and never handed
                // out; closing here is its single ownership release.
                unsafe { sys::close(fd) };
            }
            a.parked = None;
        }
        self.accepts[token] = None;
    }

    /// Lazily create + register the provided-buffer ring. False (latched)
    /// when the kernel lacks pbuf rings or the data plane is disabled —
    /// callers fall back to the readiness plane, never panic.
    fn ensure_pbuf(&mut self) -> bool {
        if self.pbuf.is_some() {
            return true;
        }
        if self.pbuf_disabled {
            return false;
        }
        if !dataplane_enabled() {
            self.pbuf_disabled = true;
            return false;
        }
        match PbufRing::new(self.ring_fd) {
            Ok(p) => {
                self.pbuf = Some(p);
                true
            }
            Err(e) => {
                eprintln!("uring data plane unavailable ({e}); staying on the readiness plane");
                self.pbuf_disabled = true;
                false
            }
        }
    }

    /// Register `fd` on the data plane, arming its multishot RECV.
    /// Ownership of `fd` transfers to the reactor (closed at finalize).
    /// `None` → caller keeps fd ownership and the readiness plane.
    pub(crate) fn conn_register(&mut self, fd: i32) -> Option<usize> {
        if !self.ensure_pbuf() {
            return None;
        }
        let token = match self.free_conns.pop() {
            Some(t) => t,
            None => {
                if self.conns.len() as u64 > CONN_TOKEN_MASK {
                    return None;
                }
                self.conns.push(ConnSlot { gen: 0, state: None });
                self.conns.len() - 1
            }
        };
        let gen = self.conns[token].gen;
        self.conns[token].state = Some(ConnState {
            fd,
            gen,
            queue: VecDeque::new(),
            parked: None,
            recv_armed: false,
            eof: false,
            recv_err: None,
            starved: false,
            send_active: Vec::new(),
            send_acked: 0,
            send_inflight: false,
            send_next: Vec::new(),
            send_err: false,
            closing: false,
        });
        if !self.arm_recv(token) {
            self.conns[token].state = None;
            self.free_conns.push(token);
            return None;
        }
        Some(token)
    }

    /// Stage the multishot BUFFER_SELECT RECV for `token`. False if no
    /// SQE slot was available (ring full even after a mid-loop flush).
    fn arm_recv(&mut self, token: usize) -> bool {
        let (fd, gen) = match self.conns[token].state.as_ref() {
            Some(c) if !c.recv_armed && !c.eof && !c.closing && c.recv_err.is_none() => {
                (c.fd, c.gen)
            }
            Some(c) => return c.recv_armed,
            None => return false,
        };
        let Some(sqe) = self.next_sqe() else { return false };
        // SAFETY: sqe staged by next_sqe, exclusively ours until publish.
        // No userspace address: BUFFER_SELECT makes the kernel pick a
        // pool buffer per completion (len 0 = "up to the buffer size").
        unsafe {
            (*sqe).opcode = sys::IORING_OP_RECV;
            (*sqe).fd = fd;
            (*sqe).ioprio = sys::IORING_RECV_MULTISHOT;
            (*sqe).flags = sys::IOSQE_BUFFER_SELECT;
            (*sqe).buf_index = PBUF_BGID;
            (*sqe).user_data = conn_ud(KIND_RECV, gen, token);
        }
        if let Some(c) = self.conns[token].state.as_mut() {
            c.recv_armed = true;
            c.starved = false;
        }
        true
    }

    /// Pop the next kernel-filled segment for `token`, re-arming the
    /// RECV when the kernel disarmed it (unless the conn is starved —
    /// then `recv_recycle` re-arms, so an empty pool cannot spin).
    pub(crate) fn recv_take(&mut self, token: usize) -> RecvTake {
        let Some(c) = self.conns.get_mut(token).and_then(|s| s.state.as_mut()) else {
            return RecvTake::Err(0);
        };
        if let Some(seg) = c.queue.pop_front() {
            let Some(p) = self.pbuf.as_ref() else { return RecvTake::Err(0) };
            // SAFETY: seg came from a RECV CQE naming bid, so
            // off + len <= PBUF_BUF_SZ and the pointer stays inside the
            // slab; the buffer is engine-owned until recycled.
            let ptr = unsafe { p.buf_ptr(seg.bid).add(seg.off as usize) };
            return RecvTake::Data { ptr, len: seg.len, bid: seg.bid, owns: seg.owns };
        }
        if c.eof {
            return RecvTake::Eof;
        }
        if let Some(e) = c.recv_err {
            return RecvTake::Err(e);
        }
        if !c.recv_armed && !c.starved {
            self.arm_recv(token);
        }
        RecvTake::Empty
    }

    /// Return a consumed buffer to the pool (`owns == false` halves of a
    /// fault-split segment are no-ops) and feed one starved connection.
    pub(crate) fn recv_recycle(&mut self, bid: u16, owns: bool) {
        if !owns {
            return;
        }
        if let Some(p) = self.pbuf.as_mut() {
            p.provide(bid);
            self.stats.pbuf_recycled += 1;
        }
        // One returned buffer can satisfy one starved RECV.
        while let Some(t) = self.starved.pop_front() {
            let alive = self
                .conns
                .get(t)
                .and_then(|s| s.state.as_ref())
                .is_some_and(|c| c.starved && !c.closing);
            if alive {
                self.arm_recv(t);
                break;
            }
        }
    }

    /// Queue `bytes` for ring-submitted SEND. False when the connection
    /// already failed (caller treats it like a dead socket). The bytes
    /// are copied into reactor-owned storage, so the caller's buffer is
    /// free the moment this returns.
    pub(crate) fn send_enqueue(&mut self, token: usize, bytes: &[u8]) -> bool {
        let Some(c) = self.conns.get_mut(token).and_then(|s| s.state.as_mut()) else {
            return false;
        };
        if c.send_err || c.closing {
            return false;
        }
        if bytes.is_empty() {
            return true;
        }
        if c.send_inflight {
            // send_active is frozen under the in-flight SQE; overflow
            // rides send_next and swaps in when the CQE lands.
            c.send_next.extend_from_slice(bytes);
            return true;
        }
        if c.send_active.len() > c.send_acked {
            // A previous arm_send failed (ring full); keep appending and
            // retry below.
            c.send_active.extend_from_slice(bytes);
        } else {
            c.send_active.clear();
            c.send_acked = 0;
            c.send_active.extend_from_slice(bytes);
        }
        self.arm_send(token);
        true
    }

    /// Bytes accepted by [`UringReactor::send_enqueue`] but not yet
    /// acknowledged by SEND CQEs (the engine's exit check adds this to
    /// the spool's own unsent count).
    pub(crate) fn send_pending(&self, token: usize) -> usize {
        self.conns
            .get(token)
            .and_then(|s| s.state.as_ref())
            .map_or(0, |c| c.send_pending())
    }

    /// Did a SEND complete with an error? (Pending bytes were dropped;
    /// the connection is as dead as a failed `write`.)
    pub(crate) fn send_failed(&self, token: usize) -> bool {
        self.conns.get(token).and_then(|s| s.state.as_ref()).is_some_and(|c| c.send_err)
    }

    /// Stage a SEND SQE covering `send_active[send_acked..]`. False if
    /// no SQE slot was available (retried at enqueue/park time).
    fn arm_send(&mut self, token: usize) -> bool {
        let (fd, gen, addr, len) = match self.conns[token].state.as_ref() {
            Some(c) if !c.send_inflight && !c.send_err && c.send_active.len() > c.send_acked => (
                c.fd,
                c.gen,
                c.send_active[c.send_acked..].as_ptr() as u64,
                (c.send_active.len() - c.send_acked) as u32,
            ),
            _ => return false,
        };
        let Some(sqe) = self.next_sqe() else { return false };
        // SAFETY: sqe staged by next_sqe, exclusively ours until publish.
        // addr/len reference send_active, which stays frozen (no mutation,
        // no reallocation) until this SQE's CQE clears send_inflight.
        unsafe {
            (*sqe).opcode = sys::IORING_OP_SEND;
            (*sqe).fd = fd;
            (*sqe).addr = addr;
            (*sqe).len = len;
            (*sqe).user_data = conn_ud(KIND_SEND, gen, token);
        }
        if let Some(c) = self.conns[token].state.as_mut() {
            c.send_inflight = true;
        }
        self.stats.send_sqes += 1;
        true
    }

    /// Park the connection fiber until a conn CQE (RECV, SEND settle, or
    /// cancellation) arrives. False if work is already available — the
    /// caller must not park then. Re-arms a disarmed RECV (when the
    /// caller still wants bytes) and retries a stalled SEND first, so a
    /// parked fiber always has an armed SQE to wake it.
    pub(crate) fn conn_park(
        &mut self,
        token: usize,
        fiber: FiberId,
        want_read: bool,
    ) -> bool {
        let Some(c) = self.conns.get_mut(token).and_then(|s| s.state.as_mut()) else {
            return false;
        };
        if c.closing {
            return false;
        }
        if want_read && (!c.queue.is_empty() || c.eof || c.recv_err.is_some()) {
            return false;
        }
        if c.send_err {
            return false;
        }
        let rearm_recv = want_read && !c.recv_armed && !c.starved;
        let retry_send = !c.send_inflight && c.send_active.len() > c.send_acked;
        if rearm_recv {
            self.arm_recv(token);
        }
        if retry_send {
            self.arm_send(token);
        }
        if let Some(c) = self.conns.get_mut(token).and_then(|s| s.state.as_mut()) {
            c.parked = Some(fiber);
        }
        true
    }

    /// Detach the fiber from `token`: drop undelivered input, keep the
    /// in-flight SEND alive until its CQE, then finalize (close fd,
    /// recycle queued buffers, bump the slot generation).
    pub(crate) fn conn_close(&mut self, token: usize) {
        let Some(c) = self.conns.get_mut(token).and_then(|s| s.state.as_mut()) else {
            return;
        };
        c.parked = None;
        c.closing = true;
        c.send_next.clear();
        if !c.send_inflight {
            self.finalize_conn(token);
        }
    }

    /// Free a closing slot: return its queued buffers to the pool, close
    /// the fd (cancelling the armed multishot RECV), and bump the
    /// generation so late CQEs are recognized as stale.
    fn finalize_conn(&mut self, token: usize) {
        let Some(c) = self.conns[token].state.take() else { return };
        for seg in c.queue {
            self.recv_recycle(seg.bid, seg.owns);
        }
        // SAFETY: conn_register transferred fd ownership to the reactor;
        // this is its single release. The kernel's file reference keeps
        // any in-flight op safe; the armed RECV is cancelled by the close.
        unsafe { sys::close(c.fd) };
        self.conns[token].gen = self.conns[token].gen.wrapping_add(1);
        self.free_conns.push(token);
    }

    /// Publish staged SQEs with one `io_uring_enter`. The scheduler calls
    /// this once per loop (end-of-client-phase), so an entire loop's
    /// parks — any number of connections — cost at most one syscall.
    /// Returns SQEs submitted.
    pub(crate) fn flush(&mut self) -> usize {
        let staged = self.sq_tail_local.wrapping_sub(self.sq_tail_submitted);
        // SAFETY: sq_flags points into the live ring mapping.
        let overflow =
            unsafe { (*self.sq_flags).load(Ordering::Acquire) } & sys::IORING_SQ_CQ_OVERFLOW != 0;
        if staged == 0 && !overflow {
            return 0;
        }
        // Publish: SQE bodies and array slots were plain-stored above; the
        // Release tail store makes them visible to the kernel's Acquire.
        // SAFETY: sq_tail points into the live ring mapping.
        unsafe { (*self.sq_tail).store(self.sq_tail_local, Ordering::Release) };
        // Fault injection (`faults` feature only; inline no-op otherwise):
        // a failed `io_uring_enter` — the syscall is skipped, so
        // `sq_tail_submitted` does not advance and the staged SQEs ride
        // the next flush. Safe because this function only credits
        // submissions on rc > 0.
        if crate::util::faultsim::uring_enter_fault() {
            return 0;
        }
        // GETEVENTS only when the kernel parked completions in its overflow
        // list (NODROP) — it makes the kernel flush them into the CQ.
        let flags = if overflow { sys::IORING_ENTER_GETEVENTS } else { 0 };
        // SAFETY: ring_fd is our live ring; the published tail covers
        // exactly `staged` fully-written SQEs; no EXT_ARG, so arg is null.
        let rc = unsafe {
            sys::io_uring_enter(self.ring_fd, staged, 0, flags, std::ptr::null(), 0)
        };
        self.stats.enters += 1;
        if rc > 0 {
            let n = rc as u32;
            self.sq_tail_submitted = self.sq_tail_submitted.wrapping_add(n);
            self.stats.sqes_submitted += n as u64;
            self.stats.max_sqes_per_enter = self.stats.max_sqes_per_enter.max(n as u64);
            n as usize
        } else {
            0
        }
    }

    /// Harvest completions into `out` — pure shared-memory reads, **no
    /// syscall**. The scheduler passes its recycled scratch vector.
    pub(crate) fn poll_into(&mut self, out: &mut Vec<FiberId>) {
        // SAFETY: cq_head/cq_tail point into the live ring mapping; we are
        // the only CQ consumer.
        let mut head = unsafe { (*self.cq_head).load(Ordering::Relaxed) };
        let tail = unsafe { (*self.cq_tail).load(Ordering::Acquire) };
        if head == tail {
            return;
        }
        while head != tail {
            let idx = (head & self.cq_mask) as usize;
            // SAFETY: idx < cq_entries keeps the read inside the mapping;
            // the Acquire tail load above ordered the kernel's CQE writes
            // before this copy.
            let cqe = unsafe { std::ptr::read(self.cqes.add(idx)) };
            self.handle_cqe(cqe, out);
            head = head.wrapping_add(1);
        }
        // SAFETY: as above; the Release store returns the entries to the
        // kernel after our copies are done.
        unsafe { (*self.cq_head).store(head, Ordering::Release) };
    }

    fn handle_cqe(&mut self, cqe: sys::io_uring_cqe, out: &mut Vec<FiberId>) {
        self.stats.cqes_harvested += 1;
        let payload = cqe.user_data & UD_PAYLOAD_MASK;
        match cqe.user_data >> UD_KIND_SHIFT {
            KIND_POLL => {
                let fiber = payload as FiberId;
                // Wake only a fiber we still believe parked: a stale CQE
                // (fiber already swept at shutdown, fd recycled) is dropped
                // here instead of waking an unrelated fiber.
                if self.waiters.remove(&fiber) {
                    out.push(fiber);
                }
            }
            KIND_WAKE => {
                if cqe.flags & sys::IORING_CQE_F_MORE == 0 {
                    self.wake_armed = false;
                    self.arm_wake();
                }
                if self.wake_fd >= 0 {
                    let mut val: u64 = 0;
                    // Drain the counter (nonblocking eventfd; the epoll
                    // reactor may race us to it, which is fine — the CQE
                    // itself already ended any blocking wait).
                    // SAFETY: wake_fd is the worker's live eventfd; val is a
                    // live writable u64.
                    unsafe { sys::read(self.wake_fd, &mut val as *mut u64 as *mut sys::c_void, 8) };
                }
            }
            KIND_ACCEPT => {
                let token = payload as usize;
                let more = cqe.flags & sys::IORING_CQE_F_MORE != 0;
                match self.accepts.get_mut(token).and_then(|a| a.as_mut()) {
                    Some(a) if !a.closed => {
                        if !more {
                            a.armed = false;
                        }
                        if cqe.res >= 0 {
                            a.queue.push_back(cqe.res);
                        }
                        // Transient failures (ECONNABORTED, EMFILE, …) just
                        // disarm; accept_take re-arms on the next pass.
                        if let Some(f) = a.parked.take() {
                            out.push(f);
                        }
                    }
                    _ => {
                        if cqe.res >= 0 {
                            // Late accept for a closed registration: we own
                            // the fd, nobody else will.
                            // SAFETY: fd delivered by this CQE, closed once.
                            unsafe { sys::close(cqe.res) };
                        }
                    }
                }
            }
            KIND_RECV => {
                self.stats.recv_cqes += 1;
                let (gen, token) = conn_ud_split(payload);
                let has_buf = cqe.flags & sys::IORING_CQE_F_BUFFER != 0;
                let bid = (cqe.flags >> sys::IORING_CQE_BUFFER_SHIFT) as u16;
                let live = self
                    .conns
                    .get(token)
                    .and_then(|s| s.state.as_ref())
                    .is_some_and(|c| c.gen == gen && !c.closing);
                if !live {
                    // Stale completion for a recycled/closing slot: the
                    // buffer still belongs to us — back to the pool.
                    if has_buf {
                        self.recv_recycle(bid, true);
                    }
                    return;
                }
                let more = cqe.flags & sys::IORING_CQE_F_MORE != 0;
                let mut starve = false;
                // Fault injection (`faults` feature only; inline None
                // otherwise) — lossless by construction: Short splits the
                // delivery in two (no byte dropped), Enobufs delivers the
                // data but simulates a pool-exhausted disarm so the
                // starved re-arm machinery is exercised under chaos.
                let fault =
                    if cqe.res > 0 { crate::util::faultsim::uring_recv_fault() } else { None };
                {
                    let c = self.conns[token].state.as_mut().expect("checked live above");
                    if !more {
                        c.recv_armed = false;
                    }
                    if cqe.res > 0 && has_buf {
                        let len = cqe.res as u32;
                        match fault {
                            Some(crate::util::faultsim::UringRecvFault::Short) if len >= 2 => {
                                let cut = len / 2;
                                c.queue.push_back(RecvSeg { bid, off: 0, len: cut, owns: false });
                                c.queue.push_back(RecvSeg {
                                    bid,
                                    off: cut,
                                    len: len - cut,
                                    owns: true,
                                });
                            }
                            _ => c.queue.push_back(RecvSeg { bid, off: 0, len, owns: true }),
                        }
                        if matches!(fault, Some(crate::util::faultsim::UringRecvFault::Enobufs)) {
                            c.recv_armed = false;
                            starve = true;
                        }
                    } else if cqe.res == 0 {
                        c.eof = true;
                    } else if cqe.res == -ENOBUFS_ERR {
                        starve = true;
                    } else if cqe.res < 0 {
                        c.recv_err = Some(-cqe.res);
                    }
                    if starve {
                        c.starved = true;
                    }
                    if let Some(f) = c.parked.take() {
                        out.push(f);
                    }
                }
                if starve {
                    self.stats.enobufs += 1;
                    self.starved.push_back(token);
                }
            }
            KIND_SEND => {
                let (gen, token) = conn_ud_split(payload);
                let live = self
                    .conns
                    .get(token)
                    .and_then(|s| s.state.as_ref())
                    .is_some_and(|c| c.gen == gen);
                if !live {
                    return; // stale: the slot's buffers are long freed
                }
                let mut continue_short = false;
                let mut start_next = false;
                let mut finalize = false;
                {
                    let c = self.conns[token].state.as_mut().expect("checked live above");
                    c.send_inflight = false;
                    if cqe.res < 0 {
                        // The connection is as dead as a failed write():
                        // drop pending bytes, let the fiber observe
                        // send_failed and tear down.
                        c.send_err = true;
                        c.send_active.clear();
                        c.send_next.clear();
                        c.send_acked = 0;
                    } else {
                        c.send_acked += cqe.res as usize;
                        if c.send_acked < c.send_active.len() {
                            continue_short = true;
                        } else {
                            c.send_active.clear();
                            c.send_acked = 0;
                            std::mem::swap(&mut c.send_active, &mut c.send_next);
                            start_next = !c.send_active.is_empty();
                        }
                    }
                    if c.closing && !continue_short && !start_next {
                        finalize = true;
                    }
                    if !finalize {
                        if let Some(f) = c.parked.take() {
                            out.push(f);
                        }
                    }
                }
                if continue_short {
                    self.stats.short_send_continuations += 1;
                    self.arm_send(token);
                } else if start_next {
                    self.arm_send(token);
                } else if finalize {
                    self.finalize_conn(token);
                }
            }
            _ => {}
        }
    }

    /// Submit anything staged and block until a completion arrives or
    /// `timeout_ms` expires (the idle phase's sibling of a blocking
    /// `epoll_wait`); the armed wake eventfd ends the block on
    /// [`super::Shared::inject`]/shutdown. Harvests into `out`; returns
    /// fibers woken.
    pub(crate) fn enter_wait(&mut self, timeout_ms: i32, out: &mut Vec<FiberId>) -> usize {
        let staged = self.sq_tail_local.wrapping_sub(self.sq_tail_submitted);
        // SAFETY: sq_tail points into the live ring mapping (publish before
        // the blocking enter so staged SQEs are part of the same syscall).
        unsafe { (*self.sq_tail).store(self.sq_tail_local, Ordering::Release) };
        // Fault injection (`faults` feature only; inline no-op otherwise):
        // a failed blocking enter — skip the syscall (staged SQEs stay
        // staged for the next flush) but still harvest whatever the kernel
        // already completed, like a real EINTR'd enter would.
        if crate::util::faultsim::uring_enter_fault() {
            let before = out.len();
            self.poll_into(out);
            return out.len() - before;
        }
        let ts = sys::kernel_timespec {
            tv_sec: timeout_ms as i64 / 1000,
            tv_nsec: (timeout_ms as i64 % 1000) * 1_000_000,
        };
        let arg = sys::io_uring_getevents_arg {
            sigmask: 0,
            sigmask_sz: 0,
            pad: 0,
            ts: &ts as *const sys::kernel_timespec as u64,
        };
        // SAFETY: ring_fd is our live ring; the published tail covers the
        // staged SQEs; arg/ts are live locals matching EXT_ARG's contract
        // for the duration of the call.
        let rc = unsafe {
            sys::io_uring_enter(
                self.ring_fd,
                staged,
                1,
                sys::IORING_ENTER_GETEVENTS | sys::IORING_ENTER_EXT_ARG,
                &arg as *const sys::io_uring_getevents_arg as *const sys::c_void,
                std::mem::size_of::<sys::io_uring_getevents_arg>(),
            )
        };
        self.stats.enters += 1;
        self.stats.enter_waits += 1;
        if rc > 0 {
            let n = rc as u32;
            self.sq_tail_submitted = self.sq_tail_submitted.wrapping_add(n);
            self.stats.sqes_submitted += n as u64;
            self.stats.max_sqes_per_enter = self.stats.max_sqes_per_enter.max(n as u64);
        }
        let before = out.len();
        self.poll_into(out);
        out.len() - before
    }

    /// Detach every parked waiter — poll parks and parked acceptors —
    /// into `out` (the shutdown sweep; resumed fibers re-check their exit
    /// conditions). Armed kernel-side SQEs stay armed; their late CQEs
    /// are ignored by the `waiters` membership check.
    pub(crate) fn take_all_waiters(&mut self, out: &mut Vec<FiberId>) {
        out.extend(self.waiters.drain());
        for a in self.accepts.iter_mut().flatten() {
            if let Some(f) = a.parked.take() {
                out.push(f);
            }
        }
        for s in &mut self.conns {
            if let Some(c) = s.state.as_mut() {
                if let Some(f) = c.parked.take() {
                    out.push(f);
                }
            }
        }
    }
}

impl Drop for UringReactor {
    fn drop(&mut self) {
        // Connection slots still waiting on a deferred SEND settle own
        // their fds; release them before the ring goes away (the kernel's
        // file references keep any in-flight op memory-safe).
        for s in &mut self.conns {
            if let Some(c) = s.state.take() {
                // SAFETY: conn_register transferred fd ownership to the
                // reactor; single release per slot.
                unsafe { sys::close(c.fd) };
            }
        }
        // The pbuf mappings must outlive the ring registration; drop the
        // ring fd first (which tears down the registration), then unmap.
        // SAFETY: the reactor owns both ring mappings and the ring fd;
        // each is released exactly once, here. The kernel cancels
        // still-armed SQEs when the ring fd closes.
        unsafe {
            sys::munmap(self.sqes_ptr as *mut sys::c_void, self.sqes_len);
            sys::munmap(self.ring_ptr as *mut sys::c_void, self.ring_len);
            sys::close(self.ring_fd);
        }
        if let Some(p) = self.pbuf.take() {
            // SAFETY: the pbuf ring/slab mappings are owned by the
            // reactor and unmapped exactly once, after the ring fd close
            // above ended the kernel's use of them.
            unsafe {
                sys::munmap(p.ring_ptr as *mut sys::c_void, p.ring_len);
                sys::munmap(p.slab_ptr as *mut sys::c_void, p.slab_len);
            }
        }
    }
}

/// Park the current fiber until `fd` is readable/writable via the
/// worker's uring reactor ([`crate::server::netfiber::NetPolicy::IoUring`]'s
/// sibling of [`super::reactor::wait_fd`]). Spurious wake-ups are
/// possible; callers re-check their socket and loop. Degrades to a
/// momentary park (busy-poll) when the ring is unavailable, and to a
/// yield during shutdown.
pub fn wait_fd(fd: i32, want_read: bool, want_write: bool) {
    let shutting_down = super::with_worker(|w| w.shared.shutting_down());
    if shutting_down || (!want_read && !want_write) {
        fiber::yield_now();
        return;
    }
    fiber::suspend(|id| {
        let ok = super::with_worker(|w| match w.ensure_uring() {
            Some(u) => u.register(fd, want_read, want_write, id),
            None => false,
        });
        if !ok {
            // Could not stage the poll: make ourselves runnable again
            // before the switch-out (momentary park, never stranded).
            fiber::with_executor(|e| {
                e.resume(id);
            });
        }
    });
}

/// Register the current worker's ring for multishot accept on
/// `listener_fd`. `None` when the ring is unavailable (caller falls back
/// to the epoll accept path).
pub(crate) fn accept_register(listener_fd: i32) -> Option<usize> {
    super::with_worker(|w| w.ensure_uring().and_then(|u| u.accept_register(listener_fd)))
}

/// Take the next accepted fd for `token`, if any.
pub(crate) fn accept_take(token: usize) -> Option<i32> {
    super::with_worker(|w| w.uring.as_deref_mut().and_then(|u| u.accept_take(token)))
}

/// Park the acceptor fiber until a connection (or the shutdown sweep)
/// arrives. Spurious returns possible; the caller loops.
pub(crate) fn accept_park(token: usize) {
    if super::with_worker(|w| w.shared.shutting_down()) {
        fiber::yield_now();
        return;
    }
    fiber::suspend(|id| {
        let ok = super::with_worker(|w| match w.uring.as_deref_mut() {
            Some(u) => u.accept_park(token, id),
            None => false,
        });
        if !ok {
            fiber::with_executor(|e| {
                e.resume(id);
            });
        }
    });
}

/// Tear down an accept registration on the current worker.
pub(crate) fn accept_close(token: usize) {
    super::with_worker(|w| {
        if let Some(u) = w.uring.as_deref_mut() {
            u.accept_close(token);
        }
    });
}

/// Number of uring-parked fibers on the current worker (tests/metrics).
pub fn fd_waiters() -> usize {
    super::with_worker(|w| w.uring.as_deref().map_or(0, |u| u.waiting()))
}

/// Register `fd` on the current worker's data plane. `Some(token)`
/// transfers fd ownership to the reactor; `None` (no ring, no pbuf
/// support, or the kill switch) leaves the caller on the readiness
/// plane with fd ownership intact.
pub(crate) fn conn_register(fd: i32) -> Option<usize> {
    super::with_worker(|w| w.ensure_uring().and_then(|u| u.conn_register(fd)))
}

/// Take the next kernel-filled segment for `token`.
pub(crate) fn recv_take(token: usize) -> RecvTake {
    super::with_worker(|w| match w.uring.as_deref_mut() {
        Some(u) => u.recv_take(token),
        None => RecvTake::Err(0),
    })
}

/// Return a consumed provided buffer to the pool.
pub(crate) fn recv_recycle(bid: u16, owns: bool) {
    super::with_worker(|w| {
        if let Some(u) = w.uring.as_deref_mut() {
            u.recv_recycle(bid, owns);
        }
    });
}

/// Queue response bytes for ring-submitted SEND on `token`.
pub(crate) fn send_enqueue(token: usize, bytes: &[u8]) -> bool {
    super::with_worker(|w| match w.uring.as_deref_mut() {
        Some(u) => u.send_enqueue(token, bytes),
        None => false,
    })
}

/// Bytes queued for SEND but not yet acknowledged by the kernel.
pub(crate) fn send_pending(token: usize) -> usize {
    super::with_worker(|w| w.uring.as_deref().map_or(0, |u| u.send_pending(token)))
}

/// Did the data-plane SEND path fail for `token`?
pub(crate) fn send_failed(token: usize) -> bool {
    super::with_worker(|w| w.uring.as_deref().map_or(true, |u| u.send_failed(token)))
}

/// Park the current fiber until a data-plane CQE for `token` arrives
/// (RECV delivery, SEND settle, EOF, error). Spurious returns possible;
/// the caller loops. Degrades to a yield during shutdown.
pub(crate) fn conn_park(token: usize, want_read: bool) {
    if super::with_worker(|w| w.shared.shutting_down()) {
        fiber::yield_now();
        return;
    }
    fiber::suspend(|id| {
        let ok = super::with_worker(|w| match w.uring.as_deref_mut() {
            Some(u) => u.conn_park(token, id, want_read),
            None => false,
        });
        if !ok {
            fiber::with_executor(|e| {
                e.resume(id);
            });
        }
    });
}

/// Detach the current fiber from `token` (fd closes once in-flight
/// sends settle).
pub(crate) fn conn_close(token: usize) {
    super::with_worker(|w| {
        if let Some(u) = w.uring.as_deref_mut() {
            u.conn_close(token);
        }
    });
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Write;
    use std::os::unix::io::AsRawFd;

    /// Build a standalone reactor or skip the test with a visible reason.
    /// With `TRUSTEE_REQUIRE_URING` set (CI on capable kernels), a skip
    /// becomes a failure instead.
    fn reactor_or_skip(test: &str, wake_fd: i32) -> Option<Box<UringReactor>> {
        match UringReactor::new_with_entries(wake_fd, 16) {
            Ok(r) => Some(r),
            Err(e) => {
                assert!(
                    std::env::var_os("TRUSTEE_REQUIRE_URING").is_none(),
                    "TRUSTEE_REQUIRE_URING set but io_uring unavailable: {e}"
                );
                eprintln!("SKIP {test}: io_uring unavailable ({e})");
                None
            }
        }
    }

    fn tcp_pair() -> (std::net::TcpStream, std::net::TcpStream) {
        let listener = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let client = std::net::TcpStream::connect(addr).unwrap();
        let (server, _) = listener.accept().unwrap();
        (client, server)
    }

    #[test]
    fn probe_reports() {
        match probe() {
            Ok(()) => {}
            Err(e) => eprintln!("SKIP probe_reports: io_uring unavailable ({e})"),
        }
    }

    #[test]
    fn staged_polls_submit_with_one_enter_and_wake_on_ready() {
        let Some(mut r) =
            reactor_or_skip("staged_polls_submit_with_one_enter_and_wake_on_ready", -1)
        else {
            return;
        };
        // Stage many parks; none of them is a syscall.
        let pairs: Vec<_> = (0..8).map(|_| tcp_pair()).collect();
        for (i, (_c, s)) in pairs.iter().enumerate() {
            assert!(r.register(s.as_raw_fd(), true, false, 100 + i));
        }
        assert_eq!(r.stats.enters, 0, "staging must not enter the kernel");
        assert_eq!(r.waiting(), 8);
        // One enter moves the whole batch: the submission-batching
        // contract the scheduler relies on (one enter per loop).
        assert_eq!(r.flush(), 8);
        assert_eq!(r.stats.enters, 1);
        assert_eq!(r.stats.sqes_submitted, 8);
        assert_eq!(r.stats.max_sqes_per_enter, 8);
        let mut out = Vec::new();
        r.poll_into(&mut out);
        assert!(out.is_empty(), "no data yet");
        // Make every socket readable; completions arrive without another
        // submission syscall (enter_wait used here to avoid sleeping).
        for (c, _s) in &pairs {
            let mut c = c;
            c.write_all(b"x").unwrap();
        }
        let mut woken = Vec::new();
        let deadline = std::time::Instant::now() + std::time::Duration::from_secs(5);
        while woken.len() < 8 && std::time::Instant::now() < deadline {
            r.enter_wait(100, &mut woken);
        }
        woken.sort_unstable();
        assert_eq!(woken, (100..108).collect::<Vec<_>>());
        assert_eq!(r.waiting(), 0);
    }

    #[test]
    fn wake_eventfd_pops_a_blocking_enter() {
        // SAFETY: eventfd has no memory preconditions; checked below.
        let efd = unsafe { sys::eventfd(0, sys::EFD_NONBLOCK | sys::EFD_CLOEXEC) };
        assert!(efd >= 0);
        let Some(mut r) = reactor_or_skip("wake_eventfd_pops_a_blocking_enter", efd) else {
            // SAFETY: efd created above; closed exactly once on this path.
            unsafe { sys::close(efd) };
            return;
        };
        let one: u64 = 1;
        // SAFETY: efd is the valid eventfd created above; one is a live u64.
        unsafe { sys::write(efd, &one as *const u64 as *const sys::c_void, 8) };
        let mut out = Vec::new();
        let t0 = std::time::Instant::now();
        r.enter_wait(2000, &mut out);
        assert!(out.is_empty(), "the wake produces no fiber");
        assert!(
            t0.elapsed() < std::time::Duration::from_millis(1500),
            "registered eventfd must end the blocking enter early"
        );
        // Multishot: a second wake still lands without re-arming by hand.
        // SAFETY: as above.
        unsafe { sys::write(efd, &one as *const u64 as *const sys::c_void, 8) };
        let t0 = std::time::Instant::now();
        r.enter_wait(2000, &mut out);
        assert!(t0.elapsed() < std::time::Duration::from_millis(1500));
        drop(r);
        // SAFETY: efd created by this test; closed exactly once.
        unsafe { sys::close(efd) };
    }

    #[test]
    fn shutdown_sweep_detaches_parked_fibers() {
        let Some(mut r) = reactor_or_skip("shutdown_sweep_detaches_parked_fibers", -1) else {
            return;
        };
        let (_c1, s1) = tcp_pair();
        let (_c2, s2) = tcp_pair();
        assert!(r.register(s1.as_raw_fd(), true, false, 7));
        assert!(r.register(s2.as_raw_fd(), false, true, 9));
        r.flush();
        let mut out = Vec::new();
        r.take_all_waiters(&mut out);
        out.sort_unstable();
        assert_eq!(out, vec![7, 9]);
        assert_eq!(r.waiting(), 0);
        // s2 was write-ready: its CQE may already sit in the ring. Swept
        // waiters must not be re-woken by stale completions.
        let mut late = Vec::new();
        r.enter_wait(50, &mut late);
        assert!(late.is_empty(), "stale CQEs after the sweep wake nobody");
    }

    #[test]
    fn multishot_accept_queues_connections() {
        let Some(mut r) = reactor_or_skip("multishot_accept_queues_connections", -1) else {
            return;
        };
        let listener = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
        listener.set_nonblocking(true).unwrap();
        let addr = listener.local_addr().unwrap();
        let token = r.accept_register(listener.as_raw_fd()).expect("accept_register");
        r.flush();
        assert_eq!(r.stats.enters, 1, "one enter armed the multishot accept");
        let clients: Vec<_> =
            (0..3).map(|_| std::net::TcpStream::connect(addr).unwrap()).collect();
        let mut got = Vec::new();
        let mut scratch = Vec::new();
        let deadline = std::time::Instant::now() + std::time::Duration::from_secs(5);
        while got.len() < 3 && std::time::Instant::now() < deadline {
            r.enter_wait(100, &mut scratch);
            while let Some(fd) = r.accept_take(token) {
                assert!(fd >= 0);
                // SAFETY: the CQE handed us ownership of this accepted fd;
                // wrapping it transfers that ownership to the TcpStream.
                let s = unsafe { <std::net::TcpStream as std::os::fd::FromRawFd>::from_raw_fd(fd) };
                got.push(s);
            }
        }
        assert_eq!(got.len(), 3, "one multishot SQE served every connection");
        // The single arming SQE plus nothing else was ever submitted.
        assert_eq!(r.stats.sqes_submitted, 1);
        r.accept_close(token);
        drop(clients);
    }

    #[test]
    fn pbuf_probe_reports() {
        match probe_pbuf() {
            Ok(()) => {}
            Err(e) => {
                assert!(
                    std::env::var_os("TRUSTEE_REQUIRE_URING_PBUF").is_none(),
                    "TRUSTEE_REQUIRE_URING_PBUF set but pbuf rings unavailable: {e}"
                );
                eprintln!("SKIP pbuf_probe_reports: pbuf rings unavailable ({e})");
            }
        }
    }

    /// A reactor with the data plane engaged, or a visible SKIP.
    fn pbuf_reactor_or_skip(test: &str) -> Option<Box<UringReactor>> {
        let mut r = reactor_or_skip(test, -1)?;
        if !r.ensure_pbuf() {
            assert!(
                std::env::var_os("TRUSTEE_REQUIRE_URING_PBUF").is_none(),
                "TRUSTEE_REQUIRE_URING_PBUF set but the data plane did not engage"
            );
            eprintln!("SKIP {test}: pbuf rings unavailable");
            return None;
        }
        Some(r)
    }

    #[test]
    fn data_plane_recv_send_roundtrip_without_read_syscalls() {
        let Some(mut r) =
            pbuf_reactor_or_skip("data_plane_recv_send_roundtrip_without_read_syscalls")
        else {
            return;
        };
        let (mut c, s) = tcp_pair();
        // conn_register takes fd ownership (the reactor closes it).
        let fd = <std::net::TcpStream as std::os::fd::IntoRawFd>::into_raw_fd(s);
        let token = r.conn_register(fd).expect("conn_register with a live pbuf ring");
        assert_eq!(r.flush(), 1, "one SQE armed the multishot RECV");
        c.write_all(b"hello ring").unwrap();
        let mut got = Vec::new();
        let mut scratch = Vec::new();
        let mut consumed = 0u64;
        let deadline = std::time::Instant::now() + std::time::Duration::from_secs(5);
        while got.len() < 10 && std::time::Instant::now() < deadline {
            r.enter_wait(100, &mut scratch);
            loop {
                match r.recv_take(token) {
                    RecvTake::Data { ptr, len, bid, owns } => {
                        // SAFETY: the contract of RecvTake::Data — ptr is
                        // valid for len bytes until the recycle below.
                        got.extend_from_slice(unsafe {
                            std::slice::from_raw_parts(ptr, len as usize)
                        });
                        r.recv_recycle(bid, owns);
                        if owns {
                            consumed += 1;
                        }
                    }
                    _ => break,
                }
            }
        }
        assert_eq!(&got[..], b"hello ring", "kernel-filled buffers carry the payload");
        assert!(r.stats.recv_cqes > 0, "data plane must have produced RECV CQEs");
        assert_eq!(r.stats.pbuf_recycled, consumed, "every consumed buffer recycled");

        // Ring-submitted SEND reaches the peer without a write syscall
        // from us (the enter that flushes the SQE is the only kernel
        // crossing).
        assert!(r.send_enqueue(token, b"pong"));
        r.flush();
        let mut back = [0u8; 4];
        c.set_read_timeout(Some(std::time::Duration::from_secs(5))).unwrap();
        std::io::Read::read_exact(&mut c, &mut back).unwrap();
        assert_eq!(&back, b"pong");
        assert!(r.stats.send_sqes >= 1);
        let deadline = std::time::Instant::now() + std::time::Duration::from_secs(5);
        while r.send_pending(token) > 0 && std::time::Instant::now() < deadline {
            r.enter_wait(100, &mut scratch);
        }
        assert_eq!(r.send_pending(token), 0, "SEND CQE settles the pending count");

        // Peer close surfaces as Eof after drained data.
        drop(c);
        let deadline = std::time::Instant::now() + std::time::Duration::from_secs(5);
        loop {
            match r.recv_take(token) {
                RecvTake::Eof => break,
                RecvTake::Data { bid, owns, .. } => r.recv_recycle(bid, owns),
                _ => {
                    assert!(std::time::Instant::now() < deadline, "EOF never arrived");
                    r.enter_wait(100, &mut scratch);
                }
            }
        }
        r.conn_close(token);
        assert_eq!(r.send_pending(token), 0);
    }

    #[test]
    fn data_plane_close_defers_until_send_settles() {
        let Some(mut r) = pbuf_reactor_or_skip("data_plane_close_defers_until_send_settles")
        else {
            return;
        };
        let (mut c, s) = tcp_pair();
        let fd = <std::net::TcpStream as std::os::fd::IntoRawFd>::into_raw_fd(s);
        let token = r.conn_register(fd).expect("conn_register");
        r.flush();
        assert!(r.send_enqueue(token, b"final response"));
        // Detach with the SEND still in flight: the fd must stay open
        // until the CQE lands, so the peer still receives the bytes.
        r.conn_close(token);
        r.flush();
        let mut back = [0u8; 14];
        c.set_read_timeout(Some(std::time::Duration::from_secs(5))).unwrap();
        std::io::Read::read_exact(&mut c, &mut back).unwrap();
        assert_eq!(&back, b"final response");
        let mut scratch = Vec::new();
        let deadline = std::time::Instant::now() + std::time::Duration::from_secs(5);
        while r.conns[token].state.is_some() && std::time::Instant::now() < deadline {
            r.enter_wait(100, &mut scratch);
        }
        assert!(r.conns[token].state.is_none(), "slot finalized after the SEND settled");
        // EOF after the deferred close.
        let mut rest = Vec::new();
        let _ = std::io::Read::read_to_end(&mut c, &mut rest);
        assert!(rest.is_empty());
    }
}
