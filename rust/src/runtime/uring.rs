//! Per-worker io_uring reactor: the batched-kernel-boundary sibling of
//! the epoll [`super::reactor`].
//!
//! The epoll reactor already made *idle* connections cheap, but every
//! park still pays an `epoll_ctl` syscall to re-arm its oneshot interest,
//! and every tick with waiters pays an `epoll_wait`. This reactor applies
//! the crate's delegation philosophy — batch many requests onto one
//! carrier — to the kernel boundary itself: fibers that park on fd
//! readiness *stage* a `POLL_ADD` SQE into the worker's mmap'd submission
//! ring (a few plain stores, no syscall), and the scheduler publishes the
//! whole batch with **one `io_uring_enter` per loop** from its flush
//! phase, mirroring the outbox flush-watermark discipline. Completions
//! are harvested from the mmap'd completion ring with **no syscall at
//! all**. The listener uses a single multishot `ACCEPT` SQE, so a wave of
//! new connections costs one staged SQE total, and each worker's wake
//! eventfd is armed with a multishot `POLL_ADD` so [`super::Shared::inject`]
//! and shutdown still pop a blocked `io_uring_enter` wait instantly.
//!
//! ## Ring memory-ordering contract
//!
//! The SQ/CQ rings are shared memory between this thread and the kernel
//! (DESIGN.md, "Kernel-boundary batching"):
//!
//! - **SQ (we produce, kernel consumes):** write the SQE body and the
//!   `array[idx]` slot with plain stores, then publish by storing the SQ
//!   tail with `Release`; read the kernel's SQ head with `Acquire` for
//!   the ring-full check.
//! - **CQ (kernel produces, we consume):** read the CQ tail with
//!   `Acquire`, copy CQEs out by value, then store the CQ head with
//!   `Release` so the kernel may reuse the entries.
//!
//! ## SQE lifetime / user_data
//!
//! Every SQE this reactor submits is self-contained — `POLL_ADD` and
//! `ACCEPT` (with null address buffers) carry **no userspace buffer**, so
//! there is no buffer to keep alive while an operation is in flight and
//! no ownership handoff to get wrong. Connection payload bytes keep
//! moving through the engine's ordinary non-blocking `read`/`write`
//! calls once a fiber is woken. `user_data` carries a kind tag in the
//! top byte and the payload ([`FiberId`] or accept token) below it; a
//! parked fiber is woken only while it is present in the `waiters` set,
//! so a stale CQE (shutdown swept the fiber first, or the fd was
//! recycled) is ignored rather than waking an unrelated fiber. Wake-ups
//! may still be spurious — every fd waiter re-checks its socket.

use crate::fiber::{self, FiberId};
use crate::util::sys;
use std::collections::{HashSet, VecDeque};
use std::sync::atomic::{AtomicU32, Ordering};
use std::sync::OnceLock;

/// SQ entries per worker ring (CQ gets 2x). Bounds SQEs *staged per
/// scheduler loop*, not total parked fibers (the kernel holds armed polls
/// internally after submission); an overfull loop flushes mid-stage and
/// counts it in [`UringStats::sq_full_flushes`].
const URING_ENTRIES: u32 = 4096;

/// `user_data` layout: kind tag in the top byte, payload below.
const UD_KIND_SHIFT: u32 = 56;
const UD_PAYLOAD_MASK: u64 = (1u64 << UD_KIND_SHIFT) - 1;
const KIND_POLL: u64 = 1;
const KIND_ACCEPT: u64 = 2;
const KIND_WAKE: u64 = 3;

/// Submission/completion counters (metrics + the batching contract:
/// `enters` grows by at most one per scheduler loop regardless of how
/// many connections had pending I/O).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct UringStats {
    /// `io_uring_enter` syscalls issued (submission flushes + blocking
    /// waits).
    pub enters: u64,
    /// SQEs submitted across all enters.
    pub sqes_submitted: u64,
    /// CQEs harvested from the completion ring.
    pub cqes_harvested: u64,
    /// Mid-loop flushes forced by a full SQ ring (should be ~0).
    pub sq_full_flushes: u64,
    /// Enters that blocked waiting for a completion (idle phase).
    pub enter_waits: u64,
    /// Largest SQE batch a single enter carried.
    pub max_sqes_per_enter: u64,
}

impl UringStats {
    pub fn merge(&mut self, o: &UringStats) {
        self.enters += o.enters;
        self.sqes_submitted += o.sqes_submitted;
        self.cqes_harvested += o.cqes_harvested;
        self.sq_full_flushes += o.sq_full_flushes;
        self.enter_waits += o.enter_waits;
        self.max_sqes_per_enter = self.max_sqes_per_enter.max(o.max_sqes_per_enter);
    }
}

/// One multishot-accept registration (one per listener; in practice one
/// per server).
struct AcceptState {
    listener_fd: i32,
    /// Accepted connection fds delivered by CQEs, awaiting the acceptor
    /// fiber.
    queue: VecDeque<i32>,
    /// The acceptor fiber, when parked waiting for the next connection.
    parked: Option<FiberId>,
    /// Is the multishot SQE still armed in the kernel? (A CQE without
    /// `IORING_CQE_F_MORE` disarms it; `accept_take` re-arms.)
    armed: bool,
    closed: bool,
}

/// One worker's io_uring instance: ring mappings, staged-submission
/// state, the parked-fiber set, and accept registrations.
pub struct UringReactor {
    ring_fd: i32,
    /// Wake eventfd (owned by [`super::Shared`]; armed here, not closed).
    wake_fd: i32,
    /// The (single) ring mapping and the SQE array mapping.
    ring_ptr: *mut u8,
    ring_len: usize,
    sqes_ptr: *mut sys::io_uring_sqe,
    sqes_len: usize,
    // SQ geometry/pointers (into ring_ptr).
    sq_head: *const AtomicU32,
    sq_tail: *const AtomicU32,
    sq_flags: *const AtomicU32,
    sq_mask: u32,
    sq_entries: u32,
    sq_array: *mut u32,
    // CQ geometry/pointers (into ring_ptr).
    cq_head: *const AtomicU32,
    cq_tail: *const AtomicU32,
    cq_mask: u32,
    cqes: *const sys::io_uring_cqe,
    /// Local (unpublished) SQ tail and the value last published+entered.
    sq_tail_local: u32,
    sq_tail_submitted: u32,
    /// Fibers parked on a POLL_ADD; a CQE wakes a fiber only while its id
    /// is in here (stale CQEs are ignored).
    waiters: HashSet<FiberId>,
    /// Is the wake eventfd's multishot poll currently armed?
    wake_armed: bool,
    accepts: Vec<Option<AcceptState>>,
    pub stats: UringStats,
}

/// Probe io_uring availability once per process: ring creation, the
/// feature bits the reactor depends on, and the ring mappings. Servers
/// resolve `NetPolicy::IoUring` through this and fall back to epoll
/// (with the returned reason) when it fails.
pub fn probe() -> Result<(), String> {
    static PROBE: OnceLock<Result<(), String>> = OnceLock::new();
    PROBE
        .get_or_init(|| UringReactor::new_with_entries(-1, 8).map(drop))
        .clone()
}

impl UringReactor {
    /// Build a reactor around a fresh ring, arming the worker's wake
    /// eventfd (when valid) with a multishot poll so cross-worker wakes
    /// end a blocking [`UringReactor::enter_wait`] instantly.
    pub(crate) fn new(wake_fd: i32) -> Result<Box<UringReactor>, String> {
        Self::new_with_entries(wake_fd, URING_ENTRIES)
    }

    fn new_with_entries(wake_fd: i32, entries: u32) -> Result<Box<UringReactor>, String> {
        let mut p = sys::io_uring_params::default();
        // SAFETY: p is a live zeroed params block; the fd is checked below.
        let ring_fd = unsafe { sys::io_uring_setup(entries, &mut p) };
        if ring_fd < 0 {
            return Err(format!("io_uring_setup: {}", std::io::Error::last_os_error()));
        }
        // Close the fd on any early return below.
        struct FdGuard(i32);
        impl Drop for FdGuard {
            fn drop(&mut self) {
                if self.0 >= 0 {
                    // SAFETY: the guard owns the fd; closed exactly once.
                    unsafe { sys::close(self.0) };
                }
            }
        }
        let mut guard = FdGuard(ring_fd);
        let need =
            sys::IORING_FEAT_SINGLE_MMAP | sys::IORING_FEAT_NODROP | sys::IORING_FEAT_EXT_ARG;
        if p.features & need != need {
            return Err(format!(
                "io_uring features {:#x} lack required SINGLE_MMAP|NODROP|EXT_ARG (kernel too old)",
                p.features
            ));
        }
        let sq_sz = p.sq_off.array as usize + p.sq_entries as usize * std::mem::size_of::<u32>();
        let cq_sz = p.cq_off.cqes as usize
            + p.cq_entries as usize * std::mem::size_of::<sys::io_uring_cqe>();
        let ring_len = sq_sz.max(cq_sz);
        // SAFETY: mapping the just-created ring fd at the documented offset;
        // checked against MAP_FAILED before use.
        let ring_ptr = unsafe {
            sys::mmap(
                std::ptr::null_mut(),
                ring_len,
                sys::PROT_READ | sys::PROT_WRITE,
                sys::MAP_SHARED | sys::MAP_POPULATE,
                ring_fd,
                sys::IORING_OFF_SQ_RING,
            )
        };
        if ring_ptr == sys::MAP_FAILED {
            return Err(format!("io_uring ring mmap: {}", std::io::Error::last_os_error()));
        }
        let sqes_len = p.sq_entries as usize * std::mem::size_of::<sys::io_uring_sqe>();
        // SAFETY: as above, at the SQE-array offset; checked before use. On
        // failure the ring mapping is released before returning.
        let sqes_ptr = unsafe {
            sys::mmap(
                std::ptr::null_mut(),
                sqes_len,
                sys::PROT_READ | sys::PROT_WRITE,
                sys::MAP_SHARED | sys::MAP_POPULATE,
                ring_fd,
                sys::IORING_OFF_SQES,
            )
        };
        if sqes_ptr == sys::MAP_FAILED {
            let e = std::io::Error::last_os_error();
            // SAFETY: ring_ptr is the live mapping created above; unmapped
            // exactly once on this early-exit path.
            unsafe { sys::munmap(ring_ptr, ring_len) };
            return Err(format!("io_uring sqes mmap: {e}"));
        }
        let base = ring_ptr as *mut u8;
        // SAFETY: all offsets come from the kernel's params block and lie
        // within the mapping; the kernel guarantees natural alignment, so
        // casting the u32 head/tail/flags words to AtomicU32 is sound.
        let (sq_head, sq_tail, sq_flags, sq_mask, sq_entries, sq_array, tail0) = unsafe {
            (
                base.add(p.sq_off.head as usize) as *const AtomicU32,
                base.add(p.sq_off.tail as usize) as *const AtomicU32,
                base.add(p.sq_off.flags as usize) as *const AtomicU32,
                *(base.add(p.sq_off.ring_mask as usize) as *const u32),
                *(base.add(p.sq_off.ring_entries as usize) as *const u32),
                base.add(p.sq_off.array as usize) as *mut u32,
                (*(base.add(p.sq_off.tail as usize) as *const AtomicU32)).load(Ordering::Acquire),
            )
        };
        // SAFETY: same justification as the SQ pointer derivations above.
        let (cq_head, cq_tail, cq_mask, cqes) = unsafe {
            (
                base.add(p.cq_off.head as usize) as *const AtomicU32,
                base.add(p.cq_off.tail as usize) as *const AtomicU32,
                *(base.add(p.cq_off.ring_mask as usize) as *const u32),
                base.add(p.cq_off.cqes as usize) as *const sys::io_uring_cqe,
            )
        };
        guard.0 = -1; // ownership moves into the reactor
        let mut r = Box::new(UringReactor {
            ring_fd,
            wake_fd,
            ring_ptr: ring_ptr as *mut u8,
            ring_len,
            sqes_ptr: sqes_ptr as *mut sys::io_uring_sqe,
            sqes_len,
            sq_head,
            sq_tail,
            sq_flags,
            sq_mask,
            sq_entries,
            sq_array,
            cq_head,
            cq_tail,
            cq_mask,
            cqes,
            sq_tail_local: tail0,
            sq_tail_submitted: tail0,
            waiters: HashSet::new(),
            wake_armed: false,
            accepts: Vec::new(),
            stats: UringStats::default(),
        });
        if wake_fd >= 0 {
            r.arm_wake();
            r.flush();
        }
        Ok(r)
    }

    /// Fibers currently parked on a poll SQE (incl. parked acceptors).
    pub fn waiting(&self) -> usize {
        self.waiters.len()
            + self.accepts.iter().flatten().filter(|a| a.parked.is_some()).count()
    }

    /// Should the idle scheduler block in this ring's `enter_wait` (vs
    /// the epoll reactor)? True while anything is parked here.
    pub fn wants_block(&self) -> bool {
        self.waiting() > 0
    }

    /// Stage one SQE, flushing mid-loop only if the ring is full. Returns
    /// a pointer valid until the next stage/flush.
    fn next_sqe(&mut self) -> Option<*mut sys::io_uring_sqe> {
        // SAFETY: sq_head points into the live ring mapping (kernel-written
        // consumer index).
        let head = unsafe { (*self.sq_head).load(Ordering::Acquire) };
        if self.sq_tail_local.wrapping_sub(head) >= self.sq_entries {
            // Ring full this loop: publish + enter now (counted; the
            // batching contract is "at most one enter per loop" in the
            // steady state, not a hard ceiling under pathological bursts).
            self.stats.sq_full_flushes += 1;
            self.flush();
            // SAFETY: as above.
            let head = unsafe { (*self.sq_head).load(Ordering::Acquire) };
            if self.sq_tail_local.wrapping_sub(head) >= self.sq_entries {
                return None;
            }
        }
        let idx = self.sq_tail_local & self.sq_mask;
        // SAFETY: idx < sq_entries, so both derived pointers stay inside
        // their mappings; the slot is ours exclusively until the tail that
        // covers it is published.
        let sqe = unsafe {
            let sqe = self.sqes_ptr.add(idx as usize);
            std::ptr::write(sqe, sys::io_uring_sqe::default());
            std::ptr::write(self.sq_array.add(idx as usize), idx);
            sqe
        };
        self.sq_tail_local = self.sq_tail_local.wrapping_add(1);
        Some(sqe)
    }

    /// Arm `fd` for one readiness event (oneshot `POLL_ADD`) and record
    /// `fiber` as its waiter. Returns false (nothing recorded) if no SQE
    /// could be staged — the caller must not park the fiber then.
    pub(crate) fn register(
        &mut self,
        fd: i32,
        want_read: bool,
        want_write: bool,
        fiber: FiberId,
    ) -> bool {
        if !want_read && !want_write {
            return false;
        }
        let mut mask = sys::POLLERR | sys::POLLHUP;
        if want_read {
            mask |= sys::POLLIN | sys::POLLRDHUP;
        }
        if want_write {
            mask |= sys::POLLOUT;
        }
        let Some(sqe) = self.next_sqe() else { return false };
        // SAFETY: sqe was just staged by next_sqe and is exclusively ours
        // until the tail publish.
        unsafe {
            (*sqe).opcode = sys::IORING_OP_POLL_ADD;
            (*sqe).fd = fd;
            (*sqe).op_flags = mask;
            (*sqe).user_data = (KIND_POLL << UD_KIND_SHIFT) | (fiber as u64 & UD_PAYLOAD_MASK);
        }
        self.waiters.insert(fiber);
        true
    }

    /// Stage the wake eventfd's multishot poll.
    fn arm_wake(&mut self) {
        if self.wake_fd < 0 || self.wake_armed {
            return;
        }
        if let Some(sqe) = self.next_sqe() {
            // SAFETY: sqe staged by next_sqe, exclusively ours until publish.
            unsafe {
                (*sqe).opcode = sys::IORING_OP_POLL_ADD;
                (*sqe).fd = self.wake_fd;
                (*sqe).op_flags = sys::POLLIN;
                (*sqe).len = sys::IORING_POLL_ADD_MULTI;
                (*sqe).user_data = KIND_WAKE << UD_KIND_SHIFT;
            }
            self.wake_armed = true;
        }
    }

    /// Register a listener for multishot accept; returns the token the
    /// acceptor fiber polls with [`UringReactor::accept_take`].
    pub(crate) fn accept_register(&mut self, listener_fd: i32) -> Option<usize> {
        let token = match self.accepts.iter().position(|a| a.is_none()) {
            Some(i) => i,
            None => {
                self.accepts.push(None);
                self.accepts.len() - 1
            }
        };
        self.accepts[token] = Some(AcceptState {
            listener_fd,
            queue: VecDeque::new(),
            parked: None,
            armed: false,
            closed: false,
        });
        if !self.arm_accept(token) {
            self.accepts[token] = None;
            return None;
        }
        Some(token)
    }

    fn arm_accept(&mut self, token: usize) -> bool {
        let fd = match &self.accepts[token] {
            Some(a) if !a.closed && !a.armed => a.listener_fd,
            _ => return self.accepts[token].as_ref().is_some_and(|a| a.armed),
        };
        let Some(sqe) = self.next_sqe() else { return false };
        // SAFETY: sqe staged by next_sqe, exclusively ours until publish.
        // addr/off stay null: we do not ask for the peer address, so the
        // SQE references no userspace memory while in flight.
        unsafe {
            (*sqe).opcode = sys::IORING_OP_ACCEPT;
            (*sqe).fd = fd;
            (*sqe).ioprio = sys::IORING_ACCEPT_MULTISHOT;
            (*sqe).op_flags = sys::SOCK_CLOEXEC;
            (*sqe).user_data = (KIND_ACCEPT << UD_KIND_SHIFT) | token as u64;
        }
        if let Some(a) = &mut self.accepts[token] {
            a.armed = true;
        }
        true
    }

    /// Pop the next accepted connection fd, re-arming the multishot SQE
    /// if the kernel disarmed it (e.g. after EMFILE). `None` means
    /// "nothing pending — park".
    pub(crate) fn accept_take(&mut self, token: usize) -> Option<i32> {
        let needs_arm = match &self.accepts[token] {
            Some(a) if !a.closed => a.queue.is_empty() && !a.armed,
            _ => false,
        };
        if needs_arm {
            self.arm_accept(token);
        }
        self.accepts[token].as_mut().and_then(|a| a.queue.pop_front())
    }

    /// Park `fiber` until a connection lands on `token`. False if the
    /// fiber must not park (work already queued, or the slot is closed).
    pub(crate) fn accept_park(&mut self, token: usize, fiber: FiberId) -> bool {
        match &mut self.accepts[token] {
            Some(a) if !a.closed && a.queue.is_empty() => {
                a.parked = Some(fiber);
                true
            }
            _ => false,
        }
    }

    /// Tear down an accept registration, closing any queued-but-untaken
    /// connection fds. Late CQEs for the token are closed on arrival.
    pub(crate) fn accept_close(&mut self, token: usize) {
        if let Some(a) = &mut self.accepts[token] {
            a.closed = true;
            while let Some(fd) = a.queue.pop_front() {
                // SAFETY: fd was delivered by an accept CQE and never handed
                // out; closing here is its single ownership release.
                unsafe { sys::close(fd) };
            }
            a.parked = None;
        }
        self.accepts[token] = None;
    }

    /// Publish staged SQEs with one `io_uring_enter`. The scheduler calls
    /// this once per loop (end-of-client-phase), so an entire loop's
    /// parks — any number of connections — cost at most one syscall.
    /// Returns SQEs submitted.
    pub(crate) fn flush(&mut self) -> usize {
        let staged = self.sq_tail_local.wrapping_sub(self.sq_tail_submitted);
        // SAFETY: sq_flags points into the live ring mapping.
        let overflow =
            unsafe { (*self.sq_flags).load(Ordering::Acquire) } & sys::IORING_SQ_CQ_OVERFLOW != 0;
        if staged == 0 && !overflow {
            return 0;
        }
        // Publish: SQE bodies and array slots were plain-stored above; the
        // Release tail store makes them visible to the kernel's Acquire.
        // SAFETY: sq_tail points into the live ring mapping.
        unsafe { (*self.sq_tail).store(self.sq_tail_local, Ordering::Release) };
        // Fault injection (`faults` feature only; inline no-op otherwise):
        // a failed `io_uring_enter` — the syscall is skipped, so
        // `sq_tail_submitted` does not advance and the staged SQEs ride
        // the next flush. Safe because this function only credits
        // submissions on rc > 0.
        if crate::util::faultsim::uring_enter_fault() {
            return 0;
        }
        // GETEVENTS only when the kernel parked completions in its overflow
        // list (NODROP) — it makes the kernel flush them into the CQ.
        let flags = if overflow { sys::IORING_ENTER_GETEVENTS } else { 0 };
        // SAFETY: ring_fd is our live ring; the published tail covers
        // exactly `staged` fully-written SQEs; no EXT_ARG, so arg is null.
        let rc = unsafe {
            sys::io_uring_enter(self.ring_fd, staged, 0, flags, std::ptr::null(), 0)
        };
        self.stats.enters += 1;
        if rc > 0 {
            let n = rc as u32;
            self.sq_tail_submitted = self.sq_tail_submitted.wrapping_add(n);
            self.stats.sqes_submitted += n as u64;
            self.stats.max_sqes_per_enter = self.stats.max_sqes_per_enter.max(n as u64);
            n as usize
        } else {
            0
        }
    }

    /// Harvest completions into `out` — pure shared-memory reads, **no
    /// syscall**. The scheduler passes its recycled scratch vector.
    pub(crate) fn poll_into(&mut self, out: &mut Vec<FiberId>) {
        // SAFETY: cq_head/cq_tail point into the live ring mapping; we are
        // the only CQ consumer.
        let mut head = unsafe { (*self.cq_head).load(Ordering::Relaxed) };
        let tail = unsafe { (*self.cq_tail).load(Ordering::Acquire) };
        if head == tail {
            return;
        }
        while head != tail {
            let idx = (head & self.cq_mask) as usize;
            // SAFETY: idx < cq_entries keeps the read inside the mapping;
            // the Acquire tail load above ordered the kernel's CQE writes
            // before this copy.
            let cqe = unsafe { std::ptr::read(self.cqes.add(idx)) };
            self.handle_cqe(cqe, out);
            head = head.wrapping_add(1);
        }
        // SAFETY: as above; the Release store returns the entries to the
        // kernel after our copies are done.
        unsafe { (*self.cq_head).store(head, Ordering::Release) };
    }

    fn handle_cqe(&mut self, cqe: sys::io_uring_cqe, out: &mut Vec<FiberId>) {
        self.stats.cqes_harvested += 1;
        let payload = cqe.user_data & UD_PAYLOAD_MASK;
        match cqe.user_data >> UD_KIND_SHIFT {
            KIND_POLL => {
                let fiber = payload as FiberId;
                // Wake only a fiber we still believe parked: a stale CQE
                // (fiber already swept at shutdown, fd recycled) is dropped
                // here instead of waking an unrelated fiber.
                if self.waiters.remove(&fiber) {
                    out.push(fiber);
                }
            }
            KIND_WAKE => {
                if cqe.flags & sys::IORING_CQE_F_MORE == 0 {
                    self.wake_armed = false;
                    self.arm_wake();
                }
                if self.wake_fd >= 0 {
                    let mut val: u64 = 0;
                    // Drain the counter (nonblocking eventfd; the epoll
                    // reactor may race us to it, which is fine — the CQE
                    // itself already ended any blocking wait).
                    // SAFETY: wake_fd is the worker's live eventfd; val is a
                    // live writable u64.
                    unsafe { sys::read(self.wake_fd, &mut val as *mut u64 as *mut sys::c_void, 8) };
                }
            }
            KIND_ACCEPT => {
                let token = payload as usize;
                let more = cqe.flags & sys::IORING_CQE_F_MORE != 0;
                match self.accepts.get_mut(token).and_then(|a| a.as_mut()) {
                    Some(a) if !a.closed => {
                        if !more {
                            a.armed = false;
                        }
                        if cqe.res >= 0 {
                            a.queue.push_back(cqe.res);
                        }
                        // Transient failures (ECONNABORTED, EMFILE, …) just
                        // disarm; accept_take re-arms on the next pass.
                        if let Some(f) = a.parked.take() {
                            out.push(f);
                        }
                    }
                    _ => {
                        if cqe.res >= 0 {
                            // Late accept for a closed registration: we own
                            // the fd, nobody else will.
                            // SAFETY: fd delivered by this CQE, closed once.
                            unsafe { sys::close(cqe.res) };
                        }
                    }
                }
            }
            _ => {}
        }
    }

    /// Submit anything staged and block until a completion arrives or
    /// `timeout_ms` expires (the idle phase's sibling of a blocking
    /// `epoll_wait`); the armed wake eventfd ends the block on
    /// [`super::Shared::inject`]/shutdown. Harvests into `out`; returns
    /// fibers woken.
    pub(crate) fn enter_wait(&mut self, timeout_ms: i32, out: &mut Vec<FiberId>) -> usize {
        let staged = self.sq_tail_local.wrapping_sub(self.sq_tail_submitted);
        // SAFETY: sq_tail points into the live ring mapping (publish before
        // the blocking enter so staged SQEs are part of the same syscall).
        unsafe { (*self.sq_tail).store(self.sq_tail_local, Ordering::Release) };
        // Fault injection (`faults` feature only; inline no-op otherwise):
        // a failed blocking enter — skip the syscall (staged SQEs stay
        // staged for the next flush) but still harvest whatever the kernel
        // already completed, like a real EINTR'd enter would.
        if crate::util::faultsim::uring_enter_fault() {
            let before = out.len();
            self.poll_into(out);
            return out.len() - before;
        }
        let ts = sys::kernel_timespec {
            tv_sec: timeout_ms as i64 / 1000,
            tv_nsec: (timeout_ms as i64 % 1000) * 1_000_000,
        };
        let arg = sys::io_uring_getevents_arg {
            sigmask: 0,
            sigmask_sz: 0,
            pad: 0,
            ts: &ts as *const sys::kernel_timespec as u64,
        };
        // SAFETY: ring_fd is our live ring; the published tail covers the
        // staged SQEs; arg/ts are live locals matching EXT_ARG's contract
        // for the duration of the call.
        let rc = unsafe {
            sys::io_uring_enter(
                self.ring_fd,
                staged,
                1,
                sys::IORING_ENTER_GETEVENTS | sys::IORING_ENTER_EXT_ARG,
                &arg as *const sys::io_uring_getevents_arg as *const sys::c_void,
                std::mem::size_of::<sys::io_uring_getevents_arg>(),
            )
        };
        self.stats.enters += 1;
        self.stats.enter_waits += 1;
        if rc > 0 {
            let n = rc as u32;
            self.sq_tail_submitted = self.sq_tail_submitted.wrapping_add(n);
            self.stats.sqes_submitted += n as u64;
            self.stats.max_sqes_per_enter = self.stats.max_sqes_per_enter.max(n as u64);
        }
        let before = out.len();
        self.poll_into(out);
        out.len() - before
    }

    /// Detach every parked waiter — poll parks and parked acceptors —
    /// into `out` (the shutdown sweep; resumed fibers re-check their exit
    /// conditions). Armed kernel-side SQEs stay armed; their late CQEs
    /// are ignored by the `waiters` membership check.
    pub(crate) fn take_all_waiters(&mut self, out: &mut Vec<FiberId>) {
        out.extend(self.waiters.drain());
        for a in self.accepts.iter_mut().flatten() {
            if let Some(f) = a.parked.take() {
                out.push(f);
            }
        }
    }
}

impl Drop for UringReactor {
    fn drop(&mut self) {
        // SAFETY: the reactor owns both mappings and the ring fd; each is
        // released exactly once, here. The kernel cancels still-armed SQEs
        // when the ring fd closes.
        unsafe {
            sys::munmap(self.sqes_ptr as *mut sys::c_void, self.sqes_len);
            sys::munmap(self.ring_ptr as *mut sys::c_void, self.ring_len);
            sys::close(self.ring_fd);
        }
    }
}

/// Park the current fiber until `fd` is readable/writable via the
/// worker's uring reactor ([`crate::server::netfiber::NetPolicy::IoUring`]'s
/// sibling of [`super::reactor::wait_fd`]). Spurious wake-ups are
/// possible; callers re-check their socket and loop. Degrades to a
/// momentary park (busy-poll) when the ring is unavailable, and to a
/// yield during shutdown.
pub fn wait_fd(fd: i32, want_read: bool, want_write: bool) {
    let shutting_down = super::with_worker(|w| w.shared.shutting_down());
    if shutting_down || (!want_read && !want_write) {
        fiber::yield_now();
        return;
    }
    fiber::suspend(|id| {
        let ok = super::with_worker(|w| match w.ensure_uring() {
            Some(u) => u.register(fd, want_read, want_write, id),
            None => false,
        });
        if !ok {
            // Could not stage the poll: make ourselves runnable again
            // before the switch-out (momentary park, never stranded).
            fiber::with_executor(|e| {
                e.resume(id);
            });
        }
    });
}

/// Register the current worker's ring for multishot accept on
/// `listener_fd`. `None` when the ring is unavailable (caller falls back
/// to the epoll accept path).
pub(crate) fn accept_register(listener_fd: i32) -> Option<usize> {
    super::with_worker(|w| w.ensure_uring().and_then(|u| u.accept_register(listener_fd)))
}

/// Take the next accepted fd for `token`, if any.
pub(crate) fn accept_take(token: usize) -> Option<i32> {
    super::with_worker(|w| w.uring.as_deref_mut().and_then(|u| u.accept_take(token)))
}

/// Park the acceptor fiber until a connection (or the shutdown sweep)
/// arrives. Spurious returns possible; the caller loops.
pub(crate) fn accept_park(token: usize) {
    if super::with_worker(|w| w.shared.shutting_down()) {
        fiber::yield_now();
        return;
    }
    fiber::suspend(|id| {
        let ok = super::with_worker(|w| match w.uring.as_deref_mut() {
            Some(u) => u.accept_park(token, id),
            None => false,
        });
        if !ok {
            fiber::with_executor(|e| {
                e.resume(id);
            });
        }
    });
}

/// Tear down an accept registration on the current worker.
pub(crate) fn accept_close(token: usize) {
    super::with_worker(|w| {
        if let Some(u) = w.uring.as_deref_mut() {
            u.accept_close(token);
        }
    });
}

/// Number of uring-parked fibers on the current worker (tests/metrics).
pub fn fd_waiters() -> usize {
    super::with_worker(|w| w.uring.as_deref().map_or(0, |u| u.waiting()))
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Write;
    use std::os::unix::io::AsRawFd;

    /// Build a standalone reactor or skip the test with a visible reason.
    /// With `TRUSTEE_REQUIRE_URING` set (CI on capable kernels), a skip
    /// becomes a failure instead.
    fn reactor_or_skip(test: &str, wake_fd: i32) -> Option<Box<UringReactor>> {
        match UringReactor::new_with_entries(wake_fd, 16) {
            Ok(r) => Some(r),
            Err(e) => {
                assert!(
                    std::env::var_os("TRUSTEE_REQUIRE_URING").is_none(),
                    "TRUSTEE_REQUIRE_URING set but io_uring unavailable: {e}"
                );
                eprintln!("SKIP {test}: io_uring unavailable ({e})");
                None
            }
        }
    }

    fn tcp_pair() -> (std::net::TcpStream, std::net::TcpStream) {
        let listener = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let client = std::net::TcpStream::connect(addr).unwrap();
        let (server, _) = listener.accept().unwrap();
        (client, server)
    }

    #[test]
    fn probe_reports() {
        match probe() {
            Ok(()) => {}
            Err(e) => eprintln!("SKIP probe_reports: io_uring unavailable ({e})"),
        }
    }

    #[test]
    fn staged_polls_submit_with_one_enter_and_wake_on_ready() {
        let Some(mut r) =
            reactor_or_skip("staged_polls_submit_with_one_enter_and_wake_on_ready", -1)
        else {
            return;
        };
        // Stage many parks; none of them is a syscall.
        let pairs: Vec<_> = (0..8).map(|_| tcp_pair()).collect();
        for (i, (_c, s)) in pairs.iter().enumerate() {
            assert!(r.register(s.as_raw_fd(), true, false, 100 + i));
        }
        assert_eq!(r.stats.enters, 0, "staging must not enter the kernel");
        assert_eq!(r.waiting(), 8);
        // One enter moves the whole batch: the submission-batching
        // contract the scheduler relies on (one enter per loop).
        assert_eq!(r.flush(), 8);
        assert_eq!(r.stats.enters, 1);
        assert_eq!(r.stats.sqes_submitted, 8);
        assert_eq!(r.stats.max_sqes_per_enter, 8);
        let mut out = Vec::new();
        r.poll_into(&mut out);
        assert!(out.is_empty(), "no data yet");
        // Make every socket readable; completions arrive without another
        // submission syscall (enter_wait used here to avoid sleeping).
        for (c, _s) in &pairs {
            let mut c = c;
            c.write_all(b"x").unwrap();
        }
        let mut woken = Vec::new();
        let deadline = std::time::Instant::now() + std::time::Duration::from_secs(5);
        while woken.len() < 8 && std::time::Instant::now() < deadline {
            r.enter_wait(100, &mut woken);
        }
        woken.sort_unstable();
        assert_eq!(woken, (100..108).collect::<Vec<_>>());
        assert_eq!(r.waiting(), 0);
    }

    #[test]
    fn wake_eventfd_pops_a_blocking_enter() {
        // SAFETY: eventfd has no memory preconditions; checked below.
        let efd = unsafe { sys::eventfd(0, sys::EFD_NONBLOCK | sys::EFD_CLOEXEC) };
        assert!(efd >= 0);
        let Some(mut r) = reactor_or_skip("wake_eventfd_pops_a_blocking_enter", efd) else {
            // SAFETY: efd created above; closed exactly once on this path.
            unsafe { sys::close(efd) };
            return;
        };
        let one: u64 = 1;
        // SAFETY: efd is the valid eventfd created above; one is a live u64.
        unsafe { sys::write(efd, &one as *const u64 as *const sys::c_void, 8) };
        let mut out = Vec::new();
        let t0 = std::time::Instant::now();
        r.enter_wait(2000, &mut out);
        assert!(out.is_empty(), "the wake produces no fiber");
        assert!(
            t0.elapsed() < std::time::Duration::from_millis(1500),
            "registered eventfd must end the blocking enter early"
        );
        // Multishot: a second wake still lands without re-arming by hand.
        // SAFETY: as above.
        unsafe { sys::write(efd, &one as *const u64 as *const sys::c_void, 8) };
        let t0 = std::time::Instant::now();
        r.enter_wait(2000, &mut out);
        assert!(t0.elapsed() < std::time::Duration::from_millis(1500));
        drop(r);
        // SAFETY: efd created by this test; closed exactly once.
        unsafe { sys::close(efd) };
    }

    #[test]
    fn shutdown_sweep_detaches_parked_fibers() {
        let Some(mut r) = reactor_or_skip("shutdown_sweep_detaches_parked_fibers", -1) else {
            return;
        };
        let (_c1, s1) = tcp_pair();
        let (_c2, s2) = tcp_pair();
        assert!(r.register(s1.as_raw_fd(), true, false, 7));
        assert!(r.register(s2.as_raw_fd(), false, true, 9));
        r.flush();
        let mut out = Vec::new();
        r.take_all_waiters(&mut out);
        out.sort_unstable();
        assert_eq!(out, vec![7, 9]);
        assert_eq!(r.waiting(), 0);
        // s2 was write-ready: its CQE may already sit in the ring. Swept
        // waiters must not be re-woken by stale completions.
        let mut late = Vec::new();
        r.enter_wait(50, &mut late);
        assert!(late.is_empty(), "stale CQEs after the sweep wake nobody");
    }

    #[test]
    fn multishot_accept_queues_connections() {
        let Some(mut r) = reactor_or_skip("multishot_accept_queues_connections", -1) else {
            return;
        };
        let listener = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
        listener.set_nonblocking(true).unwrap();
        let addr = listener.local_addr().unwrap();
        let token = r.accept_register(listener.as_raw_fd()).expect("accept_register");
        r.flush();
        assert_eq!(r.stats.enters, 1, "one enter armed the multishot accept");
        let clients: Vec<_> =
            (0..3).map(|_| std::net::TcpStream::connect(addr).unwrap()).collect();
        let mut got = Vec::new();
        let mut scratch = Vec::new();
        let deadline = std::time::Instant::now() + std::time::Duration::from_secs(5);
        while got.len() < 3 && std::time::Instant::now() < deadline {
            r.enter_wait(100, &mut scratch);
            while let Some(fd) = r.accept_take(token) {
                assert!(fd >= 0);
                // SAFETY: the CQE handed us ownership of this accepted fd;
                // wrapping it transfers that ownership to the TcpStream.
                let s = unsafe { <std::net::TcpStream as std::os::fd::FromRawFd>::from_raw_fd(fd) };
                got.push(s);
            }
        }
        assert_eq!(got.len(), 3, "one multishot SQE served every connection");
        // The single arming SQE plus nothing else was ever submitted.
        assert_eq!(r.stats.sqes_submitted, 1);
        r.accept_close(token);
        drop(clients);
    }
}
