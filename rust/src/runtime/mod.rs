//! The Trust\<T\> runtime: worker threads, the per-worker scheduler loop,
//! and the shared/dedicated trustee topology (paper §3.2, §5.2).
//!
//! Every OS worker thread is simultaneously:
//!
//! - a **trustee**, serving delegation requests addressed to properties it
//!   owns (scanning its column of the slot [`Matrix`]),
//! - a **client**, flushing outgoing request batches and dispatching
//!   responses (its row of the matrix), and
//! - a **fiber host**, running application fibers.
//!
//! *Dedicated* trustees (§6.1's "dedicated" configuration) are workers that
//! host no application fibers — they spend all their time serving.
//!
//! The scheduler loop interleaves, in FIFO fashion like the paper's
//! delegation fiber (§5.2): serve incoming requests → poll responses
//! (resuming fibers / running `then`-callbacks) → flush pending outgoing
//! requests → run one application fiber. Off the hot path, each worker also
//! drains an injector queue (mutex-guarded) through which non-worker
//! threads submit jobs — the paper's runtime has an equivalent start-up
//! path for entrusting initial properties and spawning root fibers.

pub mod xla_exec;

use crate::channel::{ClientEndpoint, Matrix, TrusteeEndpoint};
use crate::fiber::{self, Executor};
use crate::util::affinity;
use crate::util::cache::Backoff;
use std::cell::Cell;
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex};

/// A job injected from outside the runtime (runs on the worker's scheduler
/// stack, *not* in a fiber).
pub type Job = Box<dyn FnOnce(&mut Worker) + Send + 'static>;

/// State shared by all workers and the runtime handle.
pub struct Shared {
    pub(crate) matrix: Matrix,
    n: usize,
    dedicated: usize,
    shutdown: AtomicBool,
    stopped: AtomicBool,
    finished: AtomicUsize,
    injectors: Vec<Mutex<Vec<Job>>>,
    injector_nonempty: Vec<AtomicBool>,
}

impl Shared {
    /// Number of workers.
    pub fn n(&self) -> usize {
        self.n
    }

    /// Workers `0..dedicated()` host no application fibers.
    pub fn dedicated(&self) -> usize {
        self.dedicated
    }

    /// True once the runtime has fully stopped (workers joined); Trust
    /// handles outliving the runtime become inert.
    pub fn is_stopped(&self) -> bool {
        self.stopped.load(Ordering::Acquire)
    }

    /// Queue a job for `worker`. Panics if the runtime has stopped.
    pub fn inject(&self, worker: usize, job: Job) {
        assert!(
            !self.is_stopped(),
            "job injected into a stopped Trust<T> runtime"
        );
        self.injectors[worker].lock().unwrap().push(job);
        self.injector_nonempty[worker].store(true, Ordering::Release);
    }
}

/// Per-worker registry of entrusted properties (for cleanup at shutdown
/// and refcount-zero reclamation).
#[derive(Default)]
pub struct Registry {
    entries: Vec<Option<(usize, unsafe fn(*mut u8))>>,
    free: Vec<usize>,
    pub live: usize,
}

impl Registry {
    pub fn register(&mut self, ptr: *mut u8, drop_fn: unsafe fn(*mut u8)) -> usize {
        self.live += 1;
        match self.free.pop() {
            Some(i) => {
                self.entries[i] = Some((ptr as usize, drop_fn));
                i
            }
            None => {
                self.entries.push(Some((ptr as usize, drop_fn)));
                self.entries.len() - 1
            }
        }
    }

    /// Remove and drop the property at `idx`.
    ///
    /// # Safety
    /// `idx` must have been returned by `register` on this registry and the
    /// property must not be referenced afterwards.
    pub unsafe fn reclaim(&mut self, idx: usize) {
        let (ptr, drop_fn) = self.entries[idx].take().expect("double reclaim");
        self.free.push(idx);
        self.live -= 1;
        unsafe { drop_fn(ptr as *mut u8) };
    }

    fn drain_all(&mut self) {
        for e in self.entries.iter_mut() {
            if let Some((ptr, drop_fn)) = e.take() {
                self.live -= 1;
                // SAFETY: shutdown — no more requests will touch this prop.
                unsafe { drop_fn(ptr as *mut u8) };
            }
        }
    }
}

/// Per-worker scheduler state. Accessible from fibers and thunks running on
/// this worker's thread via [`with_worker`].
pub struct Worker {
    pub id: usize,
    pub shared: Arc<Shared>,
    pub exec: Box<Executor>,
    clients: Vec<ClientEndpoint>,
    trustees: Vec<TrusteeEndpoint>,
    in_delegated: Cell<bool>,
    pub registry: Registry,
    /// Metrics.
    pub loops: u64,
    pub served_requests: u64,
}

thread_local! {
    static WORKER: Cell<*mut Worker> = const { Cell::new(std::ptr::null_mut()) };
}

/// Run `f` with the current thread's worker. Panics off runtime threads.
pub fn with_worker<R>(f: impl FnOnce(&mut Worker) -> R) -> R {
    let p = WORKER.with(|c| c.get());
    assert!(!p.is_null(), "not on a Trust<T> runtime worker thread");
    // SAFETY: set for the worker's lifetime on this thread; crate-internal
    // callers do not hold overlapping borrows across calls.
    unsafe { f(&mut *p) }
}

/// Worker id of the current thread, if it is a runtime worker.
pub fn try_worker_id() -> Option<usize> {
    let p = WORKER.with(|c| c.get());
    if p.is_null() {
        None
    } else {
        Some(unsafe { (*p).id })
    }
}

/// Is the calling thread currently in delegated context (§3.4)?
pub fn in_delegated_context() -> bool {
    let p = WORKER.with(|c| c.get());
    !p.is_null() && unsafe { (*p).in_delegated.get() }
}

impl Worker {
    /// The client endpoint toward `trustee`.
    pub fn client_mut(&mut self, trustee: usize) -> &mut ClientEndpoint {
        &mut self.clients[trustee]
    }

    /// Flush one client edge eagerly (used right after enqueue).
    pub fn kick(&mut self, trustee: usize) {
        let pair = self.shared.matrix.pair(self.id, trustee);
        self.clients[trustee].try_flush(pair);
    }

    pub fn set_delegated(&self, v: bool) -> bool {
        self.in_delegated.replace(v)
    }

    pub fn in_delegated(&self) -> bool {
        self.in_delegated.get()
    }

    /// Serve every client's pending batch addressed to this trustee.
    /// Delegated closures run inside, with the delegated-context flag set.
    fn serve_all(&mut self) -> usize {
        let n = self.shared.n();
        let mut total = 0;
        let shared = self.shared.clone();
        let prev = self.in_delegated.replace(true);
        for c in 0..n {
            let pair = shared.matrix.pair(c, self.id);
            // SAFETY: all records were framed by the trust layer with
            // matching thunk/payload types; props are owned by this thread.
            total += unsafe { self.trustees[c].serve(pair) };
        }
        self.in_delegated.set(prev);
        self.served_requests += total as u64;
        total
    }

    /// Poll every trustee's response slot; dispatch completions (which
    /// resume fibers / run callbacks) and flush follow-up batches.
    fn poll_all(&mut self) -> usize {
        let n = self.shared.n();
        let mut total = 0;
        let shared = self.shared.clone();
        for t in 0..n {
            let pair = shared.matrix.pair(self.id, t);
            total += self.clients[t].poll(pair);
        }
        total
    }

    fn drain_injector(&mut self) -> usize {
        if !self.shared.injector_nonempty[self.id].load(Ordering::Acquire) {
            return 0;
        }
        let jobs: Vec<Job> = {
            let mut q = self.shared.injectors[self.id].lock().unwrap();
            self.shared.injector_nonempty[self.id].store(false, Ordering::Release);
            std::mem::take(&mut *q)
        };
        let count = jobs.len();
        for job in jobs {
            job(self);
        }
        count
    }

    /// Outstanding client work (unflushed or undispatched requests).
    fn pending_client_work(&self) -> usize {
        self.clients.iter().map(|c| c.pending()).sum()
    }

    /// One iteration of the scheduler loop; returns (useful, ran_fiber):
    /// `useful` counts delegation work (requests served, responses
    /// dispatched, jobs injected); `ran_fiber` whether a fiber slice ran.
    pub fn tick(&mut self) -> (usize, bool) {
        self.loops += 1;
        let mut useful = 0;
        useful += self.serve_all();
        useful += self.poll_all();
        useful += self.drain_injector();
        let ran_fiber = self.exec.run_one();
        (useful, ran_fiber)
    }

    fn main_loop(&mut self) {
        let mut backoff = Backoff::new();
        let mut announced_done = false;
        // Single-core fairness (DESIGN.md substitution #1): a worker whose
        // only activity is an idle-polling fiber (e.g. a socket fiber with
        // nothing on the wire) must not monopolize the CPU, or trustees on
        // other threads starve. After a few fiber-only ticks with zero
        // delegation progress, offer the OS a reschedule point.
        const FIBER_ONLY_YIELD: u32 = 4;
        let mut fiber_only_ticks = 0u32;
        loop {
            let (useful, ran_fiber) = self.tick();
            if useful > 0 {
                backoff.reset();
                fiber_only_ticks = 0;
            } else if ran_fiber {
                backoff.reset();
                fiber_only_ticks += 1;
                if fiber_only_ticks >= FIBER_ONLY_YIELD {
                    fiber_only_ticks = 0;
                    std::thread::yield_now();
                }
            } else {
                backoff.snooze();
            }
            if self.shared.shutdown.load(Ordering::Acquire) {
                let quiescent = self.exec.live() == 0 && self.pending_client_work() == 0;
                if quiescent && !announced_done {
                    announced_done = true;
                    self.shared.finished.fetch_add(1, Ordering::AcqRel);
                } else if !quiescent && announced_done {
                    // Late work arrived (e.g. injected refcount drop).
                    announced_done = false;
                    self.shared.finished.fetch_sub(1, Ordering::AcqRel);
                }
                // Keep serving until *everyone* is quiescent so cross-worker
                // responses still flow during teardown.
                if announced_done
                    && self.shared.finished.load(Ordering::Acquire) == self.shared.n()
                {
                    break;
                }
            }
        }
        self.registry.drain_all();
    }
}

/// Configuration for [`Runtime`].
#[derive(Clone, Debug)]
pub struct Config {
    pub workers: usize,
    /// First `dedicated` workers host no application fibers (§6.1/§6.3's
    /// dedicated-trustee configurations, e.g. Trust16/Trust24).
    pub dedicated: usize,
    pub stack_size: usize,
    /// Pin worker threads to CPUs (no-op when CPUs are scarce).
    pub pin: bool,
}

impl Default for Config {
    fn default() -> Self {
        Config {
            workers: affinity::num_cpus().max(2),
            dedicated: 0,
            stack_size: fiber::DEFAULT_STACK_SIZE,
            pin: false,
        }
    }
}

/// Builder for [`Runtime`].
#[derive(Default)]
pub struct Builder {
    cfg: Config,
}

impl Builder {
    pub fn workers(mut self, n: usize) -> Self {
        self.cfg.workers = n;
        self
    }

    pub fn dedicated_trustees(mut self, n: usize) -> Self {
        self.cfg.dedicated = n;
        self
    }

    pub fn stack_size(mut self, bytes: usize) -> Self {
        self.cfg.stack_size = bytes;
        self
    }

    pub fn pin_threads(mut self, pin: bool) -> Self {
        self.cfg.pin = pin;
        self
    }

    pub fn build(self) -> Runtime {
        Runtime::new(self.cfg)
    }
}

/// Handle to a running Trust\<T\> runtime.
pub struct Runtime {
    shared: Arc<Shared>,
    handles: Vec<std::thread::JoinHandle<()>>,
}

impl Runtime {
    pub fn builder() -> Builder {
        Builder::default()
    }

    pub fn new(cfg: Config) -> Runtime {
        assert!(cfg.workers >= 1, "need at least one worker");
        let n = cfg.workers;
        let shared = Arc::new(Shared {
            matrix: Matrix::new(n),
            n,
            dedicated: cfg.dedicated,
            shutdown: AtomicBool::new(false),
            stopped: AtomicBool::new(false),
            finished: AtomicUsize::new(0),
            injectors: (0..n).map(|_| Mutex::new(Vec::new())).collect(),
            injector_nonempty: (0..n).map(|_| AtomicBool::new(false)).collect(),
        });
        let pin_plan = affinity::plan_pinning(n, cfg.dedicated);
        let mut handles = Vec::with_capacity(n);
        let started = Arc::new(AtomicUsize::new(0));
        for id in 0..n {
            let shared = shared.clone();
            let started = started.clone();
            let stack_size = cfg.stack_size;
            let pin = cfg.pin.then_some(pin_plan[id]);
            handles.push(
                std::thread::Builder::new()
                    .name(format!("trustee-w{id}"))
                    .spawn(move || {
                        if let Some(cpu) = pin {
                            affinity::pin_to_cpu(cpu);
                        }
                        let mut exec = Executor::with_stack_size(stack_size);
                        let _guard = exec.install();
                        let mut worker = Box::new(Worker {
                            id,
                            shared: shared.clone(),
                            exec,
                            clients: (0..shared.n()).map(|_| ClientEndpoint::default()).collect(),
                            trustees: (0..shared.n())
                                .map(|_| TrusteeEndpoint::default())
                                .collect(),
                            in_delegated: Cell::new(false),
                            registry: Registry::default(),
                            loops: 0,
                            served_requests: 0,
                        });
                        WORKER.with(|c| c.set(&mut *worker));
                        started.fetch_add(1, Ordering::AcqRel);
                        worker.main_loop();
                        WORKER.with(|c| c.set(std::ptr::null_mut()));
                    })
                    .expect("spawn worker"),
            );
        }
        // Wait for all workers to come up before handing out the handle.
        while started.load(Ordering::Acquire) != n {
            std::thread::yield_now();
        }
        Runtime { shared, handles }
    }

    pub fn shared(&self) -> &Arc<Shared> {
        &self.shared
    }

    pub fn workers(&self) -> usize {
        self.shared.n()
    }

    /// A [`crate::trust::TrusteeRef`] for worker `id`.
    pub fn trustee(&self, id: usize) -> crate::trust::TrusteeRef {
        assert!(id < self.shared.n());
        crate::trust::TrusteeRef::new(self.shared.clone(), id)
    }

    /// Spawn a fiber on `worker` (fire-and-forget).
    pub fn spawn_on(&self, worker: usize, f: impl FnOnce() + Send + 'static) {
        assert!(
            worker >= self.shared.dedicated(),
            "worker {worker} is a dedicated trustee; spawn application fibers elsewhere"
        );
        self.shared.inject(
            worker,
            Box::new(move |w| {
                w.exec.spawn(f);
            }),
        );
    }

    /// Run `f` as a fiber on `worker` and block the calling (non-runtime)
    /// thread until it completes, returning its result.
    pub fn block_on<R: Send + 'static>(
        &self,
        worker: usize,
        f: impl FnOnce() -> R + Send + 'static,
    ) -> R {
        let done = Arc::new((Mutex::new(None::<std::thread::Result<R>>), Condvar::new()));
        let done2 = done.clone();
        self.shared.inject(
            worker,
            Box::new(move |w| {
                w.exec.spawn(move || {
                    let r = std::panic::catch_unwind(std::panic::AssertUnwindSafe(f));
                    let (m, cv) = &*done2;
                    *m.lock().unwrap() = Some(r);
                    cv.notify_all();
                });
            }),
        );
        let (m, cv) = &*done;
        let mut guard = m.lock().unwrap();
        while guard.is_none() {
            guard = cv.wait(guard).unwrap();
        }
        match guard.take().unwrap() {
            Ok(r) => r,
            Err(p) => std::panic::resume_unwind(p),
        }
    }

    /// Request shutdown and join all workers. Implied by `Drop`.
    pub fn shutdown(mut self) {
        self.shutdown_impl();
    }

    fn shutdown_impl(&mut self) {
        if self.handles.is_empty() {
            return;
        }
        self.shared.shutdown.store(true, Ordering::Release);
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
        self.shared.stopped.store(true, Ordering::Release);
    }
}

impl Drop for Runtime {
    fn drop(&mut self) {
        self.shutdown_impl();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn runtime_starts_and_stops() {
        let rt = Runtime::builder().workers(2).build();
        assert_eq!(rt.workers(), 2);
        rt.shutdown();
    }

    #[test]
    fn block_on_returns_value() {
        let rt = Runtime::builder().workers(2).build();
        let v = rt.block_on(0, || 40 + 2);
        assert_eq!(v, 42);
        rt.shutdown();
    }

    #[test]
    fn block_on_runs_in_fiber_context() {
        let rt = Runtime::builder().workers(1).build();
        let (in_fib, wid) = rt.block_on(0, || (fiber::in_fiber(), try_worker_id()));
        assert!(in_fib);
        assert_eq!(wid, Some(0));
        rt.shutdown();
    }

    #[test]
    fn block_on_propagates_panic() {
        let rt = Runtime::builder().workers(1).build();
        let r = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            rt.block_on(0, || panic!("fiber goes boom"));
        }));
        assert!(r.is_err());
        rt.shutdown();
    }

    #[test]
    fn spawn_on_runs() {
        let rt = Runtime::builder().workers(2).build();
        let flag = Arc::new(AtomicBool::new(false));
        let f2 = flag.clone();
        rt.spawn_on(1, move || f2.store(true, Ordering::Release));
        // Synchronize via block_on on the same worker: FIFO fiber order
        // means our fiber runs after the spawned one.
        rt.block_on(1, || {});
        assert!(flag.load(Ordering::Acquire));
        rt.shutdown();
    }

    #[test]
    fn many_block_ons_across_workers() {
        let rt = Runtime::builder().workers(3).build();
        for i in 0..30u64 {
            let w = (i % 3) as usize;
            let v = rt.block_on(w, move || i * 2);
            assert_eq!(v, i * 2);
        }
        rt.shutdown();
    }

    #[test]
    fn worker_ids_cover_range() {
        let rt = Runtime::builder().workers(3).build();
        let mut ids: Vec<usize> = (0..3)
            .map(|w| rt.block_on(w, move || try_worker_id().unwrap()))
            .collect();
        ids.sort_unstable();
        assert_eq!(ids, vec![0, 1, 2]);
        rt.shutdown();
    }

    #[test]
    #[should_panic(expected = "dedicated trustee")]
    fn spawn_on_dedicated_rejected() {
        let rt = Runtime::builder().workers(2).dedicated_trustees(1).build();
        rt.spawn_on(0, || {});
    }

    #[test]
    fn yielding_fibers_interleave_with_runtime() {
        let rt = Runtime::builder().workers(1).build();
        let v = rt.block_on(0, || {
            let mut acc = 0u64;
            for i in 0..10 {
                acc += i;
                fiber::yield_now();
            }
            acc
        });
        assert_eq!(v, 45);
        rt.shutdown();
    }
}
