//! The Trust\<T\> runtime: worker threads, the per-worker scheduler loop,
//! and the shared/dedicated trustee topology (paper §3.2, §5.2).
//!
//! Every OS worker thread is simultaneously:
//!
//! - a **trustee**, serving delegation requests addressed to properties it
//!   owns (scanning its column of the slot [`Matrix`]),
//! - a **client**, flushing outgoing request batches and dispatching
//!   responses (its row of the matrix), and
//! - a **fiber host**, running application fibers.
//!
//! *Dedicated* trustees (§6.1's "dedicated" configuration) are workers that
//! host no application fibers — they spend all their time serving.
//!
//! ## Scheduler phases
//!
//! Each loop iteration runs five phases in FIFO fashion like the paper's
//! delegation fiber (§5.2):
//!
//! 1. **serve** — drain whole request batches from every client column,
//!    repeating while batches keep arriving (bounded burst) so a hot
//!    trustee amortizes the scan, then fall back to the adaptive
//!    [`Backoff`] when idle;
//! 2. **poll** — consume completed response batches, running completions
//!    (fiber wake-ups / `then`-callbacks) *outside* any worker borrow;
//! 3. **reactor** — wake fibers whose fds became ready: the epoll
//!    [`reactor`] sweep plus the syscall-free [`uring`] completion-ring
//!    harvest; when the worker has been fully idle for a while it
//!    *blocks* here (bounded by [`IDLE_EPOLL_TIMEOUT_MS`]) — in the
//!    ring's `io_uring_enter` when fibers are uring-parked, else in
//!    `epoll_wait` — instead of backoff-spinning;
//! 4. **inject** — drain the mutex-guarded injector queue through which
//!    non-worker threads submit jobs (start-up entrusting, root fibers);
//!    injects also write the worker's wake eventfd to end an idle block;
//! 5. **client** — run one application fiber slice, then **flush** every
//!    dirty outbox (the end-of-client-phase hook of the adaptive
//!    [`FlushPolicy`]) and publish the loop's staged io_uring SQEs with
//!    at most **one `io_uring_enter`** — the same batch-at-the-boundary
//!    discipline, applied to the kernel.
//!
//! ## Borrow discipline (re-entrancy)
//!
//! Delegated thunks, response completions, injected jobs, and fiber code
//! may all re-enter [`with_worker`]. The scheduler therefore never holds a
//! `&mut Worker` across foreign code: endpoints are detached
//! (`std::mem::take`) while thunks run, response batches are detached
//! before completions run, injected jobs take no worker argument, and all
//! phase bookkeeping happens in short `with_worker` bursts. `with_worker`
//! itself hands out a fresh reborrow from the thread-local raw pointer at
//! every call, so nested calls never alias a live long-lived borrow.

pub mod reactor;
pub mod uring;
#[cfg(feature = "xla")]
pub mod xla_exec;

use crate::channel::{ClientEndpoint, Completion, FlushPolicy, Matrix, Thunk, TrusteeEndpoint};
use crate::codec::WireWriter;
use crate::fiber::{self, Executor};
use crate::util::affinity;
use crate::util::cache::Backoff;
use crate::util::sys;
use std::cell::Cell;
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex};

/// A job injected from outside the runtime. It runs on the worker's
/// scheduler stack (*not* in a fiber) with no worker borrow held — use
/// [`with_worker`] / [`fiber::with_executor`] inside for short accesses.
pub type Job = Box<dyn FnOnce() + Send + 'static>;

/// How many serve rounds a single scheduler tick may burst through while
/// request batches keep arriving (keeps a hot trustee from starving its
/// own fibers and clients).
const SERVE_BURST: usize = 8;

/// How many scheduler loops pass between runs of the **maintenance
/// phase** (registered [`Worker::register_maintenance`] callbacks — the
/// item store's incremental expiry sweep). Each callback bounds its own
/// work per call; this bounds how often the scheduler pays for it. An
/// active worker reaches it in microseconds; a fully idle one (1 ms
/// epoll blocks) still runs maintenance every few tens of ms, which
/// bounds the reclamation latency of expired-but-unaccessed items.
const MAINTENANCE_EVERY: u64 = 64;

/// Consecutive fully-idle ticks (no serve/poll/inject progress, no fiber
/// ran) before a worker stops backoff-spinning and blocks in `epoll_wait`.
/// High enough that request/response gaps in an active RPC exchange never
/// trip it; an actually-idle worker reaches it in well under a millisecond.
const IDLE_EPOLL_TICKS: u32 = 256;

/// Upper bound on one idle block in `epoll_wait`. Delegation batches
/// arriving over the slot matrix carry no fd signal, so this bounds the
/// latency they can see from a sleeping trustee; injected jobs and fd
/// readiness interrupt the block immediately (eventfd / epoll).
pub(crate) const IDLE_EPOLL_TIMEOUT_MS: i32 = 1;

/// State shared by all workers and the runtime handle.
pub struct Shared {
    pub(crate) matrix: Matrix,
    n: usize,
    dedicated: usize,
    flush_policy: FlushPolicy,
    shutdown: AtomicBool,
    stopped: AtomicBool,
    finished: AtomicUsize,
    injectors: Vec<Mutex<Vec<Job>>>,
    injector_nonempty: Vec<AtomicBool>,
    /// Per-worker wake eventfds (-1 when unavailable): written by
    /// [`Shared::inject`] and at shutdown so a worker blocked in its
    /// reactor's `epoll_wait` wakes immediately.
    wake_fds: Vec<sys::c_int>,
    /// Consecutive fully-idle ticks before a worker blocks in `epoll_wait`
    /// (configurable via [`Builder::idle_ticks`]; default
    /// [`IDLE_EPOLL_TICKS`]).
    idle_ticks: u32,
}

impl Shared {
    /// Number of workers.
    pub fn n(&self) -> usize {
        self.n
    }

    /// Workers `0..dedicated()` host no application fibers.
    pub fn dedicated(&self) -> usize {
        self.dedicated
    }

    /// The client-side flush policy every worker runs with.
    pub fn flush_policy(&self) -> FlushPolicy {
        self.flush_policy
    }

    /// True once the runtime has fully stopped (workers joined); Trust
    /// handles outliving the runtime become inert.
    pub fn is_stopped(&self) -> bool {
        self.stopped.load(Ordering::Acquire)
    }

    /// Has shutdown been requested (workers may still be draining)?
    pub fn shutting_down(&self) -> bool {
        self.shutdown.load(Ordering::Acquire)
    }

    /// Queue a job for `worker`. Panics if the runtime has stopped.
    pub fn inject(&self, worker: usize, job: Job) {
        assert!(
            !self.is_stopped(),
            "job injected into a stopped Trust<T> runtime"
        );
        self.injectors[worker].lock().unwrap().push(job);
        self.injector_nonempty[worker].store(true, Ordering::Release);
        self.wake(worker);
    }

    /// Pop `worker` out of an idle `epoll_wait` block, if it is in one.
    pub(crate) fn wake(&self, worker: usize) {
        let fd = self.wake_fds[worker];
        if fd >= 0 {
            let one: u64 = 1;
            // SAFETY: fd is the worker's live eventfd; the write reads 8 bytes
            // from a live u64.
            unsafe { sys::write(fd, &one as *const u64 as *const sys::c_void, 8) };
        }
    }
}

impl Drop for Shared {
    fn drop(&mut self) {
        for &fd in &self.wake_fds {
            if fd >= 0 {
                // SAFETY: the Shared owns its wake fds; each is closed exactly once, here.
                unsafe { sys::close(fd) };
            }
        }
    }
}

/// Per-worker registry of entrusted properties (for cleanup at shutdown
/// and refcount-zero reclamation).
#[derive(Default)]
pub struct Registry {
    entries: Vec<Option<(usize, unsafe fn(*mut u8))>>,
    free: Vec<usize>,
    pub live: usize,
}

impl Registry {
    pub fn register(&mut self, ptr: *mut u8, drop_fn: unsafe fn(*mut u8)) -> usize {
        self.live += 1;
        match self.free.pop() {
            Some(i) => {
                self.entries[i] = Some((ptr as usize, drop_fn));
                i
            }
            None => {
                self.entries.push(Some((ptr as usize, drop_fn)));
                self.entries.len() - 1
            }
        }
    }

    /// Remove the entry at `idx` and hand it to the caller, who must run
    /// `drop_fn(ptr)` — *outside* any worker borrow, because dropping a
    /// property may recursively clone/drop other trusts on this worker.
    /// Panics on double reclaim.
    pub fn take_entry(&mut self, idx: usize) -> (usize, unsafe fn(*mut u8)) {
        let e = self.entries[idx].take().expect("double reclaim");
        self.free.push(idx);
        self.live -= 1;
        e
    }

    /// Detach the first remaining entry (shutdown path). One-at-a-time so
    /// that a drop which recursively reclaims *other* entries (a property
    /// holding trusts to same-worker properties) finds them still present.
    fn take_next(&mut self) -> Option<(usize, unsafe fn(*mut u8))> {
        for (idx, e) in self.entries.iter_mut().enumerate() {
            if e.is_some() {
                let entry = e.take().unwrap();
                self.free.push(idx);
                self.live -= 1;
                return Some(entry);
            }
        }
        None
    }
}

/// Remove the property at `idx` from the current worker's registry and
/// drop it with no worker borrow held.
///
/// # Safety
/// `idx` must have been returned by `register` on this worker's registry
/// and the property must not be referenced afterwards.
pub(crate) unsafe fn reclaim_on_current_worker(idx: usize) {
    let (ptr, drop_fn) = with_worker(|w| w.registry.take_entry(idx));
    // SAFETY: per the function contract; the borrow above has ended.
    unsafe { drop_fn(ptr as *mut u8) };
}

/// Per-worker scheduler state. Accessible from fibers and thunks running on
/// this worker's thread via [`with_worker`].
pub struct Worker {
    pub id: usize,
    pub shared: Arc<Shared>,
    pub exec: Box<Executor>,
    flush_policy: FlushPolicy,
    clients: Vec<ClientEndpoint>,
    trustees: Vec<TrusteeEndpoint>,
    in_delegated: Cell<bool>,
    /// Column whose endpoint the serve phase has detached right now
    /// (`usize::MAX` when none): re-entrant serving — the clone-ack spin's
    /// rc-increment sweep — must skip it, both because the placeholder
    /// endpoint's toggle state is meaningless and because that column's
    /// slot holds the very batch being served.
    serving_column: Cell<usize>,
    /// Readiness reactor (fd parking for socket fibers + idle blocking).
    pub reactor: reactor::Reactor,
    /// io_uring reactor, created lazily on the first uring fd wait
    /// ([`Worker::ensure_uring`]); workers that never see
    /// `NetPolicy::IoUring` traffic pay nothing for it.
    uring: Option<Box<uring::UringReactor>>,
    /// A uring creation attempt failed on this worker (don't retry every
    /// wait; the failure was already logged).
    uring_failed: bool,
    /// Recycled scratch for ready-fiber harvests (epoll + uring), so the
    /// steady network path allocates nothing per scheduler tick.
    wake_scratch: Vec<fiber::FiberId>,
    pub registry: Registry,
    /// Maintenance callbacks run every [`MAINTENANCE_EVERY`] scheduler
    /// loops (see [`Worker::register_maintenance`]). Dropped at the
    /// *start* of shutdown — before quiescence — so callbacks holding
    /// `Trust` handles release their refcounts while every worker is
    /// still serving.
    maintenance: Vec<Box<dyn FnMut() -> usize>>,
    /// Metrics.
    pub loops: u64,
    pub served_requests: u64,
    /// Serve rounds executed (≥ loops; burst draining adds rounds).
    pub serve_rounds: u64,
}

thread_local! {
    static WORKER: Cell<*mut Worker> = const { Cell::new(std::ptr::null_mut()) };
}

/// Run `f` with the current thread's worker. Panics off runtime threads.
///
/// Each call hands out a fresh short-lived reborrow from the thread-local
/// raw pointer. Callers must not stash the reference, and crate code never
/// holds one across foreign code (thunks, completions, fibers, jobs) — see
/// the module docs' borrow discipline.
pub fn with_worker<R>(f: impl FnOnce(&mut Worker) -> R) -> R {
    let p = WORKER.with(|c| c.get());
    assert!(!p.is_null(), "not on a Trust<T> runtime worker thread");
    // SAFETY: set for the worker's lifetime on this thread; the borrow
    // discipline above keeps reborrows disjoint.
    unsafe { f(&mut *p) }
}

/// Worker id of the current thread, if it is a runtime worker.
pub fn try_worker_id() -> Option<usize> {
    let p = WORKER.with(|c| c.get());
    if p.is_null() {
        None
    } else {
        // SAFETY: non-null means WORKER points at this thread's Worker, which
        // lives for the thread's lifetime.
        Some(unsafe { (*p).id })
    }
}

/// Is the calling thread currently in delegated context (§3.4)?
pub fn in_delegated_context() -> bool {
    let p = WORKER.with(|c| c.get());
    // SAFETY: checked non-null — points at this thread's live Worker.
    !p.is_null() && unsafe { (*p).in_delegated.get() }
}

impl Worker {
    /// The client endpoint toward `trustee`.
    pub fn client_mut(&mut self, trustee: usize) -> &mut ClientEndpoint {
        &mut self.clients[trustee]
    }

    /// Frame a request directly into the outbox arena toward `trustee`
    /// (see [`ClientEndpoint::enqueue_framed`] — reserve/commit, no temp
    /// framing buffer) and apply the flush policy: publish immediately
    /// when `urgent` (a blocking caller needs the response), under
    /// [`FlushPolicy::Eager`], or past the outbox watermarks; otherwise
    /// leave it for the end-of-phase flush.
    #[allow(clippy::too_many_arguments)]
    pub fn enqueue_framed(
        &mut self,
        trustee: usize,
        thunk: Thunk,
        prop: *mut u8,
        env: &[u8],
        completion: Completion,
        urgent: bool,
        write_args: impl FnOnce(&mut WireWriter),
    ) {
        let ep = &mut self.clients[trustee];
        ep.enqueue_framed(thunk, prop, env, completion, write_args);
        if urgent || self.flush_policy == FlushPolicy::Eager || ep.wants_flush() {
            let pair = self.shared.matrix.pair(self.id, trustee);
            self.clients[trustee].try_flush(pair);
        }
    }

    /// Flush one client edge eagerly (used by blocking call sites).
    pub fn kick(&mut self, trustee: usize) {
        let pair = self.shared.matrix.pair(self.id, trustee);
        self.clients[trustee].try_flush(pair);
    }

    /// Drive one edge without dispatching completions (see
    /// [`ClientEndpoint::poll_detach`]): consume a completed response
    /// batch onto the deferred queue and publish the next batch. Used by
    /// the clone-ack spin, which must not run foreign completions.
    pub fn poll_detach(&mut self, trustee: usize) -> bool {
        let pair = self.shared.matrix.pair(self.id, trustee);
        self.clients[trustee].poll_detach(pair)
    }

    /// Flush every dirty outbox (the end-of-client-phase hook). Returns
    /// requests published.
    pub fn flush_all(&mut self) -> usize {
        let mut flushed = 0;
        for t in 0..self.shared.n() {
            let pair = self.shared.matrix.pair(self.id, t);
            flushed += self.clients[t].try_flush(pair);
        }
        flushed
    }

    /// Register a periodic maintenance callback on this worker: called
    /// from the scheduler loop every [`MAINTENANCE_EVERY`] ticks, on the
    /// scheduler stack with **no worker borrow held** (callbacks may
    /// re-enter [`with_worker`], e.g. through the local delegation
    /// shortcut). Each callback must bound its own work per call and
    /// return a useful-work count (nonzero resets the idle backoff).
    /// Callbacks live until shutdown; they are dropped — with no borrow
    /// held — when shutdown begins, so captured `Trust` handles release
    /// cleanly while peers still serve.
    pub fn register_maintenance(&mut self, f: Box<dyn FnMut() -> usize>) {
        self.maintenance.push(f);
    }

    pub fn set_delegated(&self, v: bool) -> bool {
        self.in_delegated.replace(v)
    }

    pub fn in_delegated(&self) -> bool {
        self.in_delegated.get()
    }

    /// Outstanding client work (unflushed or undispatched requests).
    fn pending_client_work(&self) -> usize {
        self.clients.iter().map(|c| c.pending()).sum()
    }

    /// Batches this worker has published across all edges (metrics).
    pub fn flushes(&self) -> u64 {
        self.clients.iter().map(|c| c.batches).sum()
    }

    /// Mean requests per published batch across all edges (metrics); 0.0
    /// before the first flush.
    pub fn batch_occupancy(&self) -> f64 {
        let batches = self.flushes();
        if batches == 0 {
            return 0.0;
        }
        let reqs: u64 = self.clients.iter().map(|c| c.flushed_requests).sum();
        reqs as f64 / batches as f64
    }

    /// Heap-byte backpressure flushes across all edges (metrics).
    pub fn backpressure_hits(&self) -> u64 {
        self.clients.iter().map(|c| c.backpressure_hits).sum()
    }

    /// The worker's io_uring reactor, creating it on first use. Returns
    /// `None` — after logging the reason, once — when the kernel can't
    /// provide a ring; callers degrade (busy-poll park, epoll accept).
    pub(crate) fn ensure_uring(&mut self) -> Option<&mut uring::UringReactor> {
        if self.uring.is_none() && !self.uring_failed {
            match uring::UringReactor::new(self.shared.wake_fds[self.id]) {
                Ok(r) => self.uring = Some(r),
                Err(e) => {
                    self.uring_failed = true;
                    eprintln!(
                        "trustee worker {}: io_uring reactor unavailable ({e}); \
                         uring fd waits degrade to busy-poll",
                        self.id
                    );
                }
            }
        }
        self.uring.as_deref_mut()
    }

    /// This worker's io_uring submission/completion counters (zeros when
    /// the ring was never created).
    pub fn uring_stats(&self) -> uring::UringStats {
        self.uring.as_deref().map(|u| u.stats).unwrap_or_default()
    }

    /// Hot-path allocation/copy counters aggregated over this worker's
    /// client and trustee endpoints (DESIGN.md, "Allocation discipline").
    /// Each worker owns its endpoints, so the underlying counters are
    /// plain (non-atomic) fields bumped on the hot path and summed here
    /// on demand.
    pub fn hot_path_stats(&self) -> HotPathStats {
        let mut s = HotPathStats::default();
        for c in &self.clients {
            s.completion_heap_spills += c.completion_heap_spills;
            s.heap_records += c.heap_records;
            s.heap_pool_hits += c.heap_pool.hits;
            s.heap_pool_misses += c.heap_pool.misses;
            s.slot_bytes_copied += c.slot_bytes_copied;
        }
        for t in &self.trustees {
            s.heap_pool_hits += t.heap_pool.hits;
            s.heap_pool_misses += t.heap_pool.misses;
            s.slot_bytes_copied += t.slot_bytes_copied;
        }
        s
    }
}

/// Per-worker hot-path allocation and copy counters (see
/// [`Worker::hot_path_stats`]); `merge` folds workers into totals.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct HotPathStats {
    /// Completions whose captures exceeded the inline budget and fell
    /// back to a heap box (should be ~0 at steady state).
    pub completion_heap_spills: u64,
    /// Requests whose payload took the out-of-line heap escape hatch.
    pub heap_records: u64,
    /// Heap free-list hits/misses (out-of-line payloads + response
    /// spills) across all endpoints.
    pub heap_pool_hits: u64,
    pub heap_pool_misses: u64,
    /// Bytes memcpy'd into request/response slots — the one copy each
    /// direction of a delegation pays.
    pub slot_bytes_copied: u64,
}

impl HotPathStats {
    pub fn merge(&mut self, other: &HotPathStats) {
        self.completion_heap_spills += other.completion_heap_spills;
        self.heap_records += other.heap_records;
        self.heap_pool_hits += other.heap_pool_hits;
        self.heap_pool_misses += other.heap_pool_misses;
        self.slot_bytes_copied += other.slot_bytes_copied;
    }
}

// ---------------------------------------------------------------------
// Scheduler phases (free functions: no `&mut Worker` held across foreign
// code — see the module docs' borrow discipline)
// ---------------------------------------------------------------------

/// Serve phase: drain whole batches from every client column, bursting up
/// to [`SERVE_BURST`] rounds while requests keep arriving. Delegated
/// closures run inside with the delegated-context flag set and with the
/// column's endpoint detached from the worker.
fn serve_phase() -> usize {
    let (n, id, shared) = with_worker(|w| (w.shared.n(), w.id, w.shared.clone()));
    let prev = with_worker(|w| w.set_delegated(true));
    let mut total = 0;
    let mut rounds = 0usize;
    loop {
        let mut round = 0;
        for c in 0..n {
            let mut ep = with_worker(|w| {
                w.serving_column.set(c);
                std::mem::take(&mut w.trustees[c])
            });
            // SAFETY: all records were framed by the trust layer with
            // matching thunk/payload types; props are owned by this thread.
            round += unsafe { ep.serve(shared.matrix.pair(c, id)) };
            with_worker(|w| {
                w.trustees[c] = ep;
                w.serving_column.set(usize::MAX);
            });
        }
        rounds += 1;
        total += round;
        if round == 0 || rounds >= SERVE_BURST {
            break;
        }
    }
    with_worker(|w| {
        w.set_delegated(prev);
        w.served_requests += total as u64;
        w.serve_rounds += rounds as u64;
    });
    total
}

/// Serve *refcount-increment-only* batches addressed to this trustee —
/// the mutual-clone cycle breaker (DESIGN.md, refcount ordering contract).
///
/// Called from the clone-ack spin in [`crate::trust`]: two trustees that
/// clone each other's properties inside delegated closures at the same
/// instant both take the spin path, and each one's `+1` can only be
/// applied by the other. While spinning, each serves incoming batches that
/// consist *solely* of records admitted by `admit` (the trust layer passes
/// its rc-increment thunks). Those thunks touch only the property header
/// and never re-enter the runtime or run user code, so applying them while
/// a delegated closure holds `&mut T` is sound — which is why, uniquely,
/// this runs under a held worker borrow instead of detaching endpoints.
/// The column currently being served (if any) is skipped: its slot holds
/// the in-progress batch.
pub(crate) fn serve_rc_increment_batches(admit: fn(u64) -> bool) -> usize {
    with_worker(|w| {
        let shared = w.shared.clone();
        let id = w.id;
        let skip = w.serving_column.get();
        let mut total = 0;
        for c in 0..shared.n() {
            if c == skip {
                continue;
            }
            // SAFETY: records were framed by the trust layer; the admit
            // pre-scan rejects any batch holding a non-rc-increment record
            // before a single thunk runs.
            total += unsafe { w.trustees[c].serve_filtered(shared.matrix.pair(c, id), admit) };
        }
        w.served_requests += total as u64;
        total
    })
}

/// Poll one client edge: consume a completed response batch, dispatch its
/// completions in order (no worker borrow held), publish the next batch.
/// Batches parked by a spin-waiting clone ack ([`Worker::poll_detach`])
/// are dispatched first so dispatch order always matches submission order.
pub(crate) fn poll_client_edge(trustee: usize) -> usize {
    let (id, shared) = with_worker(|w| (w.id, w.shared.clone()));
    let pair = shared.matrix.pair(id, trustee);
    let mut total = 0;
    while let Some(batch) = with_worker(|w| w.clients[trustee].pop_deferred()) {
        let (n, scratch, spare) = batch.dispatch();
        with_worker(|w| w.clients[trustee].finish_poll(pair, n, scratch, spare));
        total += n;
    }
    match with_worker(|w| w.clients[trustee].begin_poll(pair)) {
        Some(batch) => {
            let (n, scratch, spare) = batch.dispatch();
            with_worker(|w| w.clients[trustee].finish_poll(pair, n, scratch, spare));
            total += n;
        }
        None => {
            if total == 0 {
                // Nothing in flight: opportunistically publish queued
                // requests so the edge keeps moving.
                with_worker(|w| w.kick(trustee));
            }
        }
    }
    total
}

/// Poll phase: every trustee's response slot.
fn poll_phase() -> usize {
    let n = with_worker(|w| w.shared.n());
    let mut total = 0;
    for t in 0..n {
        total += poll_client_edge(t);
    }
    total
}

/// Injector phase: drain jobs submitted by non-worker threads. Jobs run
/// with no worker borrow held.
fn injector_phase() -> usize {
    let jobs: Vec<Job> = with_worker(|w| {
        if !w.shared.injector_nonempty[w.id].load(Ordering::Acquire) {
            return Vec::new();
        }
        let mut q = w.shared.injectors[w.id].lock().unwrap();
        w.shared.injector_nonempty[w.id].store(false, Ordering::Release);
        std::mem::take(&mut *q)
    });
    let count = jobs.len();
    for job in jobs {
        job();
    }
    count
}

/// Flush phase: the end-of-client-phase hook of the adaptive policy.
fn flush_phase() -> usize {
    with_worker(|w| w.flush_all())
}

/// Maintenance phase: run the registered per-worker callbacks (the item
/// store's incremental expiry sweep). The vector is detached while the
/// callbacks run — they are foreign code that may re-enter
/// [`with_worker`] (local delegation shortcut) — and re-attached after,
/// preserving any callbacks registered re-entrantly in the meantime.
fn maintenance_phase() -> usize {
    let mut cbs = with_worker(|w| std::mem::take(&mut w.maintenance));
    if cbs.is_empty() {
        return 0;
    }
    let mut useful = 0;
    for f in cbs.iter_mut() {
        useful += f();
    }
    with_worker(|w| {
        if w.maintenance.is_empty() {
            w.maintenance = cbs;
        } else {
            // Callbacks registered while we ran: keep both.
            let newer = std::mem::take(&mut w.maintenance);
            cbs.extend(newer);
            w.maintenance = cbs;
        }
    });
    useful
}

/// Shutdown: drop the maintenance callbacks with no worker borrow held.
/// Their captures may hold `Trust` handles whose drop re-enters the
/// runtime (refcount decrements toward other workers), so this runs at
/// the *start* of shutdown — while every worker still serves — not after
/// the registry drain.
fn drop_maintenance() {
    let cbs = with_worker(|w| std::mem::take(&mut w.maintenance));
    drop(cbs);
}

/// Resume each harvested fiber with no worker borrow held, then hand the
/// (cleared) scratch vector back to the worker for the next tick.
fn resume_scratch(mut scratch: Vec<fiber::FiberId>) -> usize {
    let n = scratch.len();
    for &id in &scratch {
        // Resume outside the worker borrow; defensively, in case an id was
        // recycled between the poll and this wake (it cannot be today —
        // fd-parked fibers are woken only here — but resume_if_parked makes
        // that a no-op rather than a panic).
        fiber::with_executor(|e| {
            e.resume_if_parked(id);
        });
    }
    scratch.clear();
    with_worker(|w| {
        if w.wake_scratch.capacity() < scratch.capacity() {
            w.wake_scratch = scratch;
        }
    });
    n
}

/// Reactor phase: wake fibers whose fds became ready. With `timeout_ms` 0
/// this is the per-tick sweep (a no-op syscall-wise while nothing is
/// parked); an idle worker passes [`IDLE_EPOLL_TIMEOUT_MS`] to *sleep* in
/// `epoll_wait` instead of backoff-spinning. Uses the worker's recycled
/// scratch vector — no allocation per tick. Returns fibers woken.
fn reactor_phase(timeout_ms: i32) -> usize {
    let mut scratch = with_worker(|w| std::mem::take(&mut w.wake_scratch));
    with_worker(|w| w.reactor.poll_into(timeout_ms, &mut scratch));
    resume_scratch(scratch)
}

/// Uring harvest phase: drain the completion ring (pure shared-memory
/// reads — **no syscall**) and wake the parked fibers. A worker without a
/// ring returns immediately.
fn uring_phase() -> usize {
    let has = with_worker(|w| w.uring.is_some());
    if !has {
        return 0;
    }
    let mut scratch = with_worker(|w| std::mem::take(&mut w.wake_scratch));
    with_worker(|w| {
        if let Some(u) = w.uring.as_deref_mut() {
            u.poll_into(&mut scratch);
        }
    });
    resume_scratch(scratch)
}

/// Uring flush phase: publish every SQE staged this loop with at most one
/// `io_uring_enter` — the kernel-boundary sibling of [`flush_phase`]'s
/// outbox publish. Runs after the client phase so all of a loop's parks
/// ride the same syscall.
fn uring_flush_phase() -> usize {
    with_worker(|w| w.uring.as_deref_mut().map_or(0, |u| u.flush()))
}

/// Idle block: sleep (bounded) waiting for readiness instead of
/// backoff-spinning. Prefer the ring's `io_uring_enter` while fibers are
/// uring-parked — their completions raise no epoll signal — otherwise
/// block in `epoll_wait`. Injected jobs end either block immediately via
/// the wake eventfd (registered in both). Returns fibers woken.
fn idle_block_phase(timeout_ms: i32) -> usize {
    let uring_blocks = with_worker(|w| w.uring.as_deref().is_some_and(|u| u.wants_block()));
    if !uring_blocks {
        return reactor_phase(timeout_ms);
    }
    let mut scratch = with_worker(|w| std::mem::take(&mut w.wake_scratch));
    with_worker(|w| {
        if let Some(u) = w.uring.as_deref_mut() {
            u.enter_wait(timeout_ms, &mut scratch);
        }
    });
    resume_scratch(scratch)
}

/// Shutdown sweep: resume every fd-parked fiber (epoll- and uring-parked,
/// plus parked acceptors) so it can re-check its exit conditions;
/// parked-on-fd fibers would otherwise hang teardown.
fn wake_all_fd_waiters() {
    let mut scratch = with_worker(|w| std::mem::take(&mut w.wake_scratch));
    with_worker(|w| {
        w.reactor.take_all_waiters_into(&mut scratch);
        if let Some(u) = w.uring.as_deref_mut() {
            u.take_all_waiters(&mut scratch);
        }
    });
    resume_scratch(scratch);
}

/// Shutdown path: drop every property still registered on this worker,
/// one at a time so recursive reclaims (and drops that entrust anew) stay
/// coherent, each drop running with no worker borrow held.
fn drain_registry() {
    while let Some((ptr, drop_fn)) = with_worker(|w| w.registry.take_next()) {
        // SAFETY: shutdown — no more requests will touch this prop.
        unsafe { drop_fn(ptr as *mut u8) };
    }
}

/// The per-worker scheduler loop. Runs on the worker's scheduler stack
/// with the thread-local worker installed; holds no worker borrow across
/// phases.
fn worker_loop() {
    let shared = with_worker(|w| w.shared.clone());
    let mut backoff = Backoff::new();
    let mut announced_done = false;
    // Single-core fairness (DESIGN.md substitution #1): a worker whose
    // only activity is an idle-polling fiber (e.g. a socket fiber with
    // nothing on the wire) must not monopolize the CPU, or trustees on
    // other threads starve. After a few fiber-only ticks with zero
    // delegation progress, offer the OS a reschedule point.
    const FIBER_ONLY_YIELD: u32 = 4;
    let mut fiber_only_ticks = 0u32;
    let mut idle_ticks = 0u32;
    let mut maintenance_live = true;
    loop {
        let loops = with_worker(|w| {
            w.loops += 1;
            w.loops
        });
        let mut useful = serve_phase();
        useful += poll_phase();
        useful += reactor_phase(0);
        useful += uring_phase();
        useful += injector_phase();
        let ran_fiber = fiber::with_executor(|e| e.run_one());
        flush_phase();
        // One io_uring_enter covers every SQE staged anywhere this loop.
        uring_flush_phase();
        let shutting_down = shared.shutdown.load(Ordering::Acquire);
        if maintenance_live && !shutting_down && loops % MAINTENANCE_EVERY == 0 {
            useful += maintenance_phase();
        }
        if shutting_down {
            // Fibers parked on fds must drain, not sleep, during teardown.
            wake_all_fd_waiters();
            if maintenance_live {
                maintenance_live = false;
                drop_maintenance();
            }
        }
        if useful > 0 {
            backoff.reset();
            fiber_only_ticks = 0;
            idle_ticks = 0;
        } else if ran_fiber {
            backoff.reset();
            idle_ticks = 0;
            fiber_only_ticks += 1;
            if fiber_only_ticks >= FIBER_ONLY_YIELD {
                fiber_only_ticks = 0;
                std::thread::yield_now();
            }
        } else if !shutting_down
            && idle_ticks >= shared.idle_ticks
            && with_worker(|w| {
                w.reactor.enabled() || w.uring.as_deref().is_some_and(|u| u.wants_block())
            })
        {
            // Idle worker: block (bounded) instead of spinning — in the
            // ring's io_uring_enter when fibers are uring-parked, else in
            // epoll_wait. fd readiness and injected jobs (eventfd) end the
            // block immediately; slot-matrix traffic waits out the bound.
            if idle_block_phase(IDLE_EPOLL_TIMEOUT_MS) > 0 {
                backoff.reset();
                idle_ticks = 0;
            }
        } else {
            idle_ticks += 1;
            backoff.snooze();
        }
        if shutting_down {
            let quiescent =
                with_worker(|w| w.exec.live() == 0 && w.pending_client_work() == 0);
            if quiescent && !announced_done {
                announced_done = true;
                shared.finished.fetch_add(1, Ordering::AcqRel);
            } else if !quiescent && announced_done {
                // Late work arrived (e.g. injected refcount drop).
                announced_done = false;
                shared.finished.fetch_sub(1, Ordering::AcqRel);
            }
            // Keep serving until *everyone* is quiescent so cross-worker
            // responses still flow during teardown.
            if announced_done && shared.finished.load(Ordering::Acquire) == shared.n() {
                break;
            }
        }
    }
    drain_registry();
}

/// Configuration for [`Runtime`].
#[derive(Clone, Debug)]
pub struct Config {
    pub workers: usize,
    /// First `dedicated` workers host no application fibers (§6.1/§6.3's
    /// dedicated-trustee configurations, e.g. Trust16/Trust24).
    pub dedicated: usize,
    pub stack_size: usize,
    /// Pin worker threads to CPUs (no-op when CPUs are scarce).
    pub pin: bool,
    /// Client-side batching discipline (default adaptive; eager reproduces
    /// the pre-batching behaviour for comparison benchmarks).
    pub flush_policy: FlushPolicy,
    /// Consecutive fully-idle ticks before a worker blocks in `epoll_wait`
    /// (lower = sleep sooner under light load; higher = spin longer for
    /// latency). Clamped to at least 1.
    pub idle_ticks: u32,
}

impl Default for Config {
    fn default() -> Self {
        Config {
            workers: affinity::num_cpus().max(2),
            dedicated: 0,
            stack_size: fiber::DEFAULT_STACK_SIZE,
            pin: false,
            flush_policy: FlushPolicy::Adaptive,
            idle_ticks: IDLE_EPOLL_TICKS,
        }
    }
}

/// Builder for [`Runtime`].
#[derive(Default)]
pub struct Builder {
    cfg: Config,
}

impl Builder {
    pub fn workers(mut self, n: usize) -> Self {
        self.cfg.workers = n;
        self
    }

    pub fn dedicated_trustees(mut self, n: usize) -> Self {
        self.cfg.dedicated = n;
        self
    }

    pub fn stack_size(mut self, bytes: usize) -> Self {
        self.cfg.stack_size = bytes;
        self
    }

    pub fn pin_threads(mut self, pin: bool) -> Self {
        self.cfg.pin = pin;
        self
    }

    pub fn flush_policy(mut self, policy: FlushPolicy) -> Self {
        self.cfg.flush_policy = policy;
        self
    }

    /// Idle ticks before a worker blocks in `epoll_wait` (see
    /// [`Config::idle_ticks`]).
    pub fn idle_ticks(mut self, ticks: u32) -> Self {
        self.cfg.idle_ticks = ticks;
        self
    }

    pub fn build(self) -> Runtime {
        Runtime::new(self.cfg)
    }
}

/// Handle to a fiber started with [`Runtime::spawn_on_handle`] /
/// [`Runtime::block_on`]: lets a **non-runtime** thread wait for the
/// fiber's completion and take its result (condvar-based; never call
/// `join` from a worker thread or fiber — it would block the scheduler).
pub struct JoinHandle<R> {
    done: Arc<(Mutex<Option<std::thread::Result<R>>>, Condvar)>,
}

impl<R> JoinHandle<R> {
    /// Has the fiber finished (without consuming the handle)?
    pub fn is_finished(&self) -> bool {
        self.done.0.lock().unwrap().is_some()
    }

    /// Block the calling (non-runtime) thread until the fiber completes;
    /// returns its result, re-raising a fiber panic.
    pub fn join(self) -> R {
        let (m, cv) = &*self.done;
        let mut g = m.lock().unwrap();
        while g.is_none() {
            g = cv.wait(g).unwrap();
        }
        match g.take().unwrap() {
            Ok(r) => r,
            Err(p) => std::panic::resume_unwind(p),
        }
    }
}

/// Handle to a running Trust\<T\> runtime.
pub struct Runtime {
    shared: Arc<Shared>,
    handles: Vec<std::thread::JoinHandle<()>>,
}

impl Runtime {
    pub fn builder() -> Builder {
        Builder::default()
    }

    pub fn new(cfg: Config) -> Runtime {
        assert!(cfg.workers >= 1, "need at least one worker");
        let n = cfg.workers;
        let shared = Arc::new(Shared {
            matrix: Matrix::new(n),
            n,
            dedicated: cfg.dedicated,
            flush_policy: cfg.flush_policy,
            shutdown: AtomicBool::new(false),
            stopped: AtomicBool::new(false),
            finished: AtomicUsize::new(0),
            injectors: (0..n).map(|_| Mutex::new(Vec::new())).collect(),
            injector_nonempty: (0..n).map(|_| AtomicBool::new(false)).collect(),
            wake_fds: (0..n)
                // SAFETY: eventfd has no memory preconditions; failures yield -1,
                // handled by the fd >= 0 guards at use sites.
                .map(|_| unsafe { sys::eventfd(0, sys::EFD_NONBLOCK | sys::EFD_CLOEXEC) })
                .collect(),
            idle_ticks: cfg.idle_ticks.max(1),
        });
        let pin_plan = affinity::plan_pinning(n, cfg.dedicated);
        let mut handles = Vec::with_capacity(n);
        let started = Arc::new(AtomicUsize::new(0));
        for id in 0..n {
            let shared = shared.clone();
            let started = started.clone();
            let stack_size = cfg.stack_size;
            let flush_policy = cfg.flush_policy;
            let pin = cfg.pin.then_some(pin_plan[id]);
            handles.push(
                std::thread::Builder::new()
                    .name(format!("trustee-w{id}"))
                    .spawn(move || {
                        if let Some(cpu) = pin {
                            affinity::pin_to_cpu(cpu);
                        }
                        let mut exec = Executor::with_stack_size(stack_size);
                        let _guard = exec.install();
                        let mut worker = Box::new(Worker {
                            id,
                            shared: shared.clone(),
                            exec,
                            flush_policy,
                            clients: (0..shared.n()).map(|_| ClientEndpoint::default()).collect(),
                            trustees: (0..shared.n())
                                .map(|_| TrusteeEndpoint::default())
                                .collect(),
                            in_delegated: Cell::new(false),
                            serving_column: Cell::new(usize::MAX),
                            reactor: reactor::Reactor::new(shared.wake_fds[id]),
                            uring: None,
                            uring_failed: false,
                            wake_scratch: Vec::new(),
                            registry: Registry::default(),
                            maintenance: Vec::new(),
                            loops: 0,
                            served_requests: 0,
                            serve_rounds: 0,
                        });
                        WORKER.with(|c| c.set(&mut *worker));
                        started.fetch_add(1, Ordering::AcqRel);
                        worker_loop();
                        WORKER.with(|c| c.set(std::ptr::null_mut()));
                    })
                    .expect("spawn worker"),
            );
        }
        // Wait for all workers to come up before handing out the handle.
        while started.load(Ordering::Acquire) != n {
            std::thread::yield_now();
        }
        Runtime { shared, handles }
    }

    pub fn shared(&self) -> &Arc<Shared> {
        &self.shared
    }

    pub fn workers(&self) -> usize {
        self.shared.n()
    }

    /// A [`crate::trust::TrusteeRef`] for worker `id`.
    pub fn trustee(&self, id: usize) -> crate::trust::TrusteeRef {
        assert!(id < self.shared.n());
        crate::trust::TrusteeRef::new(self.shared.clone(), id)
    }

    /// Spawn a fiber on `worker` (fire-and-forget).
    pub fn spawn_on(&self, worker: usize, f: impl FnOnce() + Send + 'static) {
        assert!(
            worker >= self.shared.dedicated(),
            "worker {worker} is a dedicated trustee; spawn application fibers elsewhere"
        );
        self.shared.inject(
            worker,
            Box::new(move || {
                fiber::with_executor(|e| {
                    e.spawn(f);
                });
            }),
        );
    }

    /// Spawn a fiber on `worker` and return a [`JoinHandle`] a non-runtime
    /// thread can use as the fiber's completion signal. Unlike
    /// [`Runtime::spawn_on`] this is also allowed on dedicated trustees
    /// (driver/diagnostic fibers, like [`Runtime::block_on`]).
    pub fn spawn_on_handle<R: Send + 'static>(
        &self,
        worker: usize,
        f: impl FnOnce() -> R + Send + 'static,
    ) -> JoinHandle<R> {
        let done = Arc::new((Mutex::new(None::<std::thread::Result<R>>), Condvar::new()));
        let done2 = done.clone();
        self.shared.inject(
            worker,
            Box::new(move || {
                fiber::with_executor(|e| {
                    e.spawn(move || {
                        let r = std::panic::catch_unwind(std::panic::AssertUnwindSafe(f));
                        let (m, cv) = &*done2;
                        *m.lock().unwrap() = Some(r);
                        cv.notify_all();
                    });
                });
            }),
        );
        JoinHandle { done }
    }

    /// Aggregate [`HotPathStats`] across all workers. Runs a short fiber
    /// on each worker to read its endpoint counters — a diagnostic, not a
    /// hot-path call. Must be called from a non-runtime thread.
    pub fn hot_path_totals(&self) -> HotPathStats {
        let mut total = HotPathStats::default();
        for w in 0..self.shared.n() {
            let s = self.block_on(w, || with_worker(|wk| wk.hot_path_stats()));
            total.merge(&s);
        }
        total
    }

    /// Aggregate [`uring::UringStats`] across all workers (zeros for
    /// workers that never created a ring). Diagnostic, like
    /// [`Runtime::hot_path_totals`]; call from a non-runtime thread.
    pub fn uring_totals(&self) -> uring::UringStats {
        let mut total = uring::UringStats::default();
        for w in 0..self.shared.n() {
            let s = self.block_on(w, || with_worker(|wk| wk.uring_stats()));
            total.merge(&s);
        }
        total
    }

    /// Run `f` as a fiber on `worker` and block the calling (non-runtime)
    /// thread until it completes, returning its result.
    pub fn block_on<R: Send + 'static>(
        &self,
        worker: usize,
        f: impl FnOnce() -> R + Send + 'static,
    ) -> R {
        self.spawn_on_handle(worker, f).join()
    }

    /// Request shutdown and join all workers. Implied by `Drop`.
    pub fn shutdown(mut self) {
        self.shutdown_impl();
    }

    fn shutdown_impl(&mut self) {
        if self.handles.is_empty() {
            return;
        }
        self.shared.shutdown.store(true, Ordering::Release);
        // Pop every worker out of an idle epoll block so teardown is prompt.
        for w in 0..self.shared.n() {
            self.shared.wake(w);
        }
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
        self.shared.stopped.store(true, Ordering::Release);
    }
}

impl Drop for Runtime {
    fn drop(&mut self) {
        self.shutdown_impl();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn runtime_starts_and_stops() {
        let rt = Runtime::builder().workers(2).build();
        assert_eq!(rt.workers(), 2);
        rt.shutdown();
    }

    #[test]
    fn block_on_returns_value() {
        let rt = Runtime::builder().workers(2).build();
        let v = rt.block_on(0, || 40 + 2);
        assert_eq!(v, 42);
        rt.shutdown();
    }

    #[test]
    fn block_on_runs_in_fiber_context() {
        let rt = Runtime::builder().workers(1).build();
        let (in_fib, wid) = rt.block_on(0, || (fiber::in_fiber(), try_worker_id()));
        assert!(in_fib);
        assert_eq!(wid, Some(0));
        rt.shutdown();
    }

    #[test]
    fn block_on_propagates_panic() {
        let rt = Runtime::builder().workers(1).build();
        let r = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            rt.block_on(0, || panic!("fiber goes boom"));
        }));
        assert!(r.is_err());
        rt.shutdown();
    }

    #[test]
    fn spawn_on_runs() {
        let rt = Runtime::builder().workers(2).build();
        let flag = Arc::new(AtomicBool::new(false));
        let f2 = flag.clone();
        rt.spawn_on(1, move || f2.store(true, Ordering::Release));
        // Synchronize via block_on on the same worker: FIFO fiber order
        // means our fiber runs after the spawned one.
        rt.block_on(1, || {});
        assert!(flag.load(Ordering::Acquire));
        rt.shutdown();
    }

    #[test]
    fn spawn_on_handle_joins_with_result() {
        let rt = Runtime::builder().workers(2).build();
        let h = rt.spawn_on_handle(1, || 6 * 7);
        assert_eq!(h.join(), 42);
        let h = rt.spawn_on_handle(0, || "done".to_string());
        while !h.is_finished() {
            std::thread::yield_now();
        }
        assert_eq!(h.join(), "done");
        rt.shutdown();
    }

    #[test]
    fn spawn_on_handle_propagates_panic() {
        let rt = Runtime::builder().workers(1).build();
        let h = rt.spawn_on_handle(0, || panic!("handled boom"));
        let r = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| h.join()));
        assert!(r.is_err());
        rt.shutdown();
    }

    #[test]
    fn many_block_ons_across_workers() {
        let rt = Runtime::builder().workers(3).build();
        for i in 0..30u64 {
            let w = (i % 3) as usize;
            let v = rt.block_on(w, move || i * 2);
            assert_eq!(v, i * 2);
        }
        rt.shutdown();
    }

    #[test]
    fn worker_ids_cover_range() {
        let rt = Runtime::builder().workers(3).build();
        let mut ids: Vec<usize> = (0..3)
            .map(|w| rt.block_on(w, move || try_worker_id().unwrap()))
            .collect();
        ids.sort_unstable();
        assert_eq!(ids, vec![0, 1, 2]);
        rt.shutdown();
    }

    #[test]
    #[should_panic(expected = "dedicated trustee")]
    fn spawn_on_dedicated_rejected() {
        let rt = Runtime::builder().workers(2).dedicated_trustees(1).build();
        rt.spawn_on(0, || {});
    }

    #[test]
    fn yielding_fibers_interleave_with_runtime() {
        let rt = Runtime::builder().workers(1).build();
        let v = rt.block_on(0, || {
            let mut acc = 0u64;
            for i in 0..10 {
                acc += i;
                fiber::yield_now();
            }
            acc
        });
        assert_eq!(v, 45);
        rt.shutdown();
    }

    #[test]
    fn flush_policy_is_configurable() {
        for policy in [FlushPolicy::Eager, FlushPolicy::Adaptive] {
            let rt = Runtime::builder().workers(2).flush_policy(policy).build();
            assert_eq!(rt.shared().flush_policy(), policy);
            let v = rt.block_on(1, move || {
                let ct = crate::trust::local_trustee().entrust(1u64);
                ct.apply(|c| *c + 1)
            });
            assert_eq!(v, 2);
            rt.shutdown();
        }
    }

    #[test]
    fn maintenance_callbacks_run_periodically_and_drop_at_shutdown() {
        let rt = Runtime::builder().workers(1).build();
        let count = Arc::new(AtomicUsize::new(0));
        let dropped = Arc::new(AtomicBool::new(false));
        struct DropFlag(Arc<AtomicBool>);
        impl Drop for DropFlag {
            fn drop(&mut self) {
                self.0.store(true, Ordering::Release);
            }
        }
        let flag = DropFlag(dropped.clone());
        let c = count.clone();
        rt.shared().inject(
            0,
            Box::new(move || {
                with_worker(|w| {
                    w.register_maintenance(Box::new(move || {
                        let _keep = &flag;
                        c.fetch_add(1, Ordering::Relaxed);
                        0
                    }));
                });
            }),
        );
        // The scheduler must call it repeatedly without any other work.
        let deadline = std::time::Instant::now() + std::time::Duration::from_secs(20);
        while count.load(Ordering::Relaxed) < 3 {
            assert!(std::time::Instant::now() < deadline, "maintenance never ran");
            std::thread::yield_now();
        }
        rt.shutdown();
        assert!(
            dropped.load(Ordering::Acquire),
            "maintenance closure must drop during shutdown"
        );
    }

    #[test]
    fn worker_metrics_accumulate() {
        let rt = Runtime::builder().workers(2).build();
        let ct = rt.block_on(0, || crate::trust::local_trustee().entrust(0u64));
        let c2 = ct.clone();
        rt.block_on(1, move || {
            for _ in 0..64 {
                c2.apply(|c| *c += 1);
            }
        });
        let (flushes, occupancy) =
            rt.block_on(1, || with_worker(|w| (w.flushes(), w.batch_occupancy())));
        assert!(flushes > 0, "blocking applies must publish batches");
        assert!(occupancy >= 1.0, "published batches carry >= 1 request");
        drop(ct);
        rt.shutdown();
    }
}
