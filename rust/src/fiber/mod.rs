//! Stackful, delegation-aware user threads (§3.3) and the per-worker
//! cooperative executor that schedules them (§5.2).
//!
//! Fibers share a kernel thread but execute on their own `mmap`'d stacks,
//! "enabling a thread to do useful work for one fiber while another waits
//! for a response from a trustee". The runtime builds on three primitives:
//!
//! - [`Executor::spawn`] — create a fiber from a closure
//! - [`suspend`] — park the current fiber, handing its id to a stash
//!   callback (the waker registers it against a pending response)
//! - [`Executor::resume`] — make a parked fiber runnable again
//!
//! Fibers never migrate across OS threads, so all executor state is
//! thread-local and entirely free of atomic instructions — one of the
//! paper's design goals (§2: "implement Trust<T> without any use of atomic
//! instructions").
//!
//! Panic policy: a panic in fiber code is caught at the fiber boundary and
//! re-thrown on the scheduler stack by [`Executor::run_one`] — panics never
//! unwind across a context switch.

mod context;
mod stack;

pub use context::Context;
pub use stack::{Stack, StackPool, DEFAULT_STACK_SIZE};

use context::{prepare_stack, raw_switch};
use std::any::Any;
use std::cell::Cell;
use std::collections::VecDeque;
use std::panic::{catch_unwind, resume_unwind, AssertUnwindSafe};

/// Identifies a fiber within its executor (slab index).
pub type FiberId = usize;

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum State {
    Ready,
    Running,
    Parked,
    Done,
}

pub(crate) struct Fiber {
    ctx: Context,
    stack: Option<Stack>,
    state: State,
    entry: Option<Box<dyn FnOnce() + 'static>>,
}

thread_local! {
    static EXEC: Cell<*mut Executor> = const { Cell::new(std::ptr::null_mut()) };
}

#[inline]
fn tls_exec() -> *mut Executor {
    let p = EXEC.with(|c| c.get());
    assert!(!p.is_null(), "no fiber executor installed on this thread");
    p
}

/// Is a fiber executor installed on this thread?
pub fn executor_installed() -> bool {
    EXEC.with(|c| !c.get().is_null())
}

/// Run a closure with mutable access to the thread's installed executor.
///
/// # Panics
/// If no executor is installed.
pub fn with_executor<R>(f: impl FnOnce(&mut Executor) -> R) -> R {
    // SAFETY: the TLS pointer is only set while the executor is pinned and
    // live (InstallGuard clears it); re-entrancy is the caller's burden and
    // all crate-internal uses are non-reentrant.
    unsafe { f(&mut *tls_exec()) }
}

/// Is the caller running inside a fiber (vs. on the scheduler stack)?
pub fn in_fiber() -> bool {
    EXEC.with(|c| {
        let p = c.get();
        // SAFETY: pointer installed by `install` and cleared before the
        // executor is dropped.
        !p.is_null() && unsafe { (*p).current.is_some() }
    })
}

/// Id of the currently running fiber, if any.
pub fn current_fiber() -> Option<FiberId> {
    EXEC.with(|c| {
        let p = c.get();
        if p.is_null() {
            None
        } else {
            // SAFETY: non-null means EXEC points at this thread's live Executor.
            unsafe { (*p).current }
        }
    })
}

/// Cooperatively yield the current fiber to the back of the ready queue.
pub fn yield_now() {
    // SAFETY: tls_exec is installed; we are inside a fiber (asserted).
    unsafe {
        let exec = tls_exec();
        let id = (*exec).current.expect("yield_now outside fiber");
        let f = (*exec).fiber_ptr(id);
        (*f).state = State::Ready;
        (*exec).ready.push_back(id);
        raw_switch(&mut (*f).ctx.rsp, (*exec).sched_ctx.rsp);
    }
}

/// Park the current fiber. `stash` receives the fiber id *before* the
/// switch; store it wherever the wake-up condition lives, then call
/// [`Executor::resume`] from this same thread to make it runnable again.
///
/// Single-thread discipline makes the handoff race-free: the resumer can
/// only run after this fiber has actually switched away.
pub fn suspend(stash: impl FnOnce(FiberId)) {
    // SAFETY: executor installed; caller is a fiber (asserted).
    unsafe {
        let exec = tls_exec();
        let id = (*exec).current.expect("suspend outside fiber context");
        let f = (*exec).fiber_ptr(id);
        (*f).state = State::Parked;
        stash(id);
        raw_switch(&mut (*f).ctx.rsp, (*exec).sched_ctx.rsp);
    }
}

/// Fiber entry point, reached via the trampoline on first switch-in.
///
/// # Safety
/// Only reached via the trampoline with `fiber` pointing at the live
/// `Fiber` whose prepared stack we are now running on.
pub(crate) unsafe extern "sysv64" fn fiber_entry(fiber: *mut Fiber) -> ! {
    // SAFETY: `fiber` is the live Box<Fiber> this stack belongs to; the
    // executor TLS pointer is installed (we got here via run_one).
    unsafe {
        let entry = (*fiber).entry.take().expect("fiber entered twice");
        let result = catch_unwind(AssertUnwindSafe(entry));
        let exec = tls_exec();
        if let Err(payload) = result {
            (*exec).pending_panic = Some(payload);
        }
        (*fiber).state = State::Done;
        // Final switch back to the scheduler; the saved rsp is dead.
        raw_switch(&mut (*fiber).ctx.rsp, (*exec).sched_ctx.rsp);
    }
    unreachable!("switched into a completed fiber")
}

/// A per-thread cooperative fiber executor.
///
/// Not `Send`/`Sync`: it must be driven by the thread that created it
/// (enforced by the raw-pointer TLS installation).
pub struct Executor {
    sched_ctx: Context,
    fibers: Vec<Option<Box<Fiber>>>,
    free: Vec<FiberId>,
    ready: VecDeque<FiberId>,
    current: Option<FiberId>,
    pool: StackPool,
    pending_panic: Option<Box<dyn Any + Send + 'static>>,
    live: usize,
    /// Cumulative count of fibers ever spawned (metrics).
    pub spawned_total: u64,
    /// Cumulative count of context switches into fibers (metrics).
    pub switches_total: u64,
    _not_send: std::marker::PhantomData<*mut ()>,
}

impl Executor {
    pub fn new() -> Box<Executor> {
        Self::with_stack_size(DEFAULT_STACK_SIZE)
    }

    pub fn with_stack_size(stack_size: usize) -> Box<Executor> {
        Box::new(Executor {
            sched_ctx: Context::empty(),
            fibers: Vec::new(),
            free: Vec::new(),
            ready: VecDeque::new(),
            current: None,
            pool: StackPool::new(stack_size, 64),
            pending_panic: None,
            live: 0,
            spawned_total: 0,
            switches_total: 0,
            _not_send: std::marker::PhantomData,
        })
    }

    /// Install this executor as the thread's executor; returns a guard that
    /// uninstalls on drop. The executor must stay pinned (hence `Box`).
    pub fn install(self: &mut Box<Executor>) -> InstallGuard {
        let ptr: *mut Executor = &mut **self;
        EXEC.with(|c| {
            assert!(c.get().is_null(), "an executor is already installed");
            c.set(ptr);
        });
        InstallGuard
    }

    fn fiber_ptr(&mut self, id: FiberId) -> *mut Fiber {
        &mut **self.fibers[id].as_mut().expect("stale fiber id") as *mut Fiber
    }

    /// Create a fiber and enqueue it as ready.
    pub fn spawn(&mut self, f: impl FnOnce() + 'static) -> FiberId {
        let stack = self.pool.get();
        let mut fiber = Box::new(Fiber {
            ctx: Context::empty(),
            stack: None,
            state: State::Ready,
            entry: Some(Box::new(f)),
        });
        let fiber_ptr: *mut Fiber = &mut *fiber;
        // SAFETY: fresh stack; prepare_stack writes only below `top`.
        fiber.ctx.rsp = unsafe { prepare_stack(stack.top(), fiber_ptr as *mut u8) };
        fiber.stack = Some(stack);

        let id = match self.free.pop() {
            Some(i) => {
                self.fibers[i] = Some(fiber);
                i
            }
            None => {
                self.fibers.push(Some(fiber));
                self.fibers.len() - 1
            }
        };
        self.live += 1;
        self.spawned_total += 1;
        self.ready.push_back(id);
        id
    }

    /// Make a parked fiber runnable. Panics if it isn't parked.
    pub fn resume(&mut self, id: FiberId) {
        let f = self.fibers[id].as_mut().expect("resume of dead fiber");
        assert_eq!(f.state, State::Parked, "resume of non-parked fiber");
        f.state = State::Ready;
        self.ready.push_back(id);
    }

    /// Make `id` runnable again if — and only if — it is currently parked;
    /// returns whether a resume happened. Wake sources that may race with a
    /// fiber's completion through id reuse (the fd reactor's shutdown
    /// sweep) use this defensive variant instead of [`Executor::resume`].
    pub fn resume_if_parked(&mut self, id: FiberId) -> bool {
        match self.fibers.get_mut(id).and_then(|f| f.as_mut()) {
            Some(f) if f.state == State::Parked => {
                f.state = State::Ready;
                self.ready.push_back(id);
                true
            }
            _ => false,
        }
    }

    /// Fibers currently parked (live, but neither ready nor running).
    pub fn parked(&self) -> usize {
        self.live - self.ready.len() - usize::from(self.current.is_some())
    }

    /// Run one ready fiber until it suspends, yields, or completes.
    /// Returns false if no fiber was ready. Must be called from the
    /// scheduler stack (never from inside a fiber).
    pub fn run_one(&mut self) -> bool {
        assert!(self.current.is_none(), "run_one called from inside a fiber");
        let Some(id) = self.ready.pop_front() else {
            return false;
        };
        let fiber_ptr = self.fiber_ptr(id);
        self.current = Some(id);
        self.switches_total += 1;
        // SAFETY: fiber_ptr is a live pinned Fiber on this thread whose ctx
        // was produced by prepare_stack or a prior switch-out.
        unsafe {
            (*fiber_ptr).state = State::Running;
            let sched_rsp: *mut *mut u8 = &mut self.sched_ctx.rsp;
            raw_switch(sched_rsp, (*fiber_ptr).ctx.rsp);
        }
        self.current = None;
        // SAFETY: fiber_ptr still live (completion only marks state).
        let done = unsafe { (*fiber_ptr).state == State::Done };
        if done {
            self.recycle(id);
        }
        if let Some(p) = self.pending_panic.take() {
            resume_unwind(p);
        }
        true
    }

    /// Drive fibers until the ready queue drains. Parked fibers stay
    /// parked. Returns the number of fiber slices executed.
    pub fn run_until_idle(&mut self) -> usize {
        let mut n = 0;
        while self.run_one() {
            n += 1;
        }
        n
    }

    fn recycle(&mut self, id: FiberId) {
        let mut fiber = self.fibers[id].take().expect("double recycle");
        if let Some(stack) = fiber.stack.take() {
            self.pool.put(stack);
        }
        self.free.push(id);
        self.live -= 1;
    }

    /// Fibers alive (ready, running, or parked).
    pub fn live(&self) -> usize {
        self.live
    }

    /// Fibers currently ready to run.
    pub fn ready_len(&self) -> usize {
        self.ready.len()
    }

    pub fn has_ready(&self) -> bool {
        !self.ready.is_empty()
    }

    /// State of a fiber id, if alive.
    pub fn state(&self, id: FiberId) -> Option<State> {
        self.fibers.get(id).and_then(|f| f.as_ref()).map(|f| f.state)
    }

    /// Number of stacks currently pooled for reuse (metrics/tests).
    pub fn pooled_stacks(&self) -> usize {
        self.pool.pooled()
    }
}

/// RAII guard for the thread-local executor installation.
pub struct InstallGuard;

impl Drop for InstallGuard {
    fn drop(&mut self) {
        EXEC.with(|c| c.set(std::ptr::null_mut()));
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::cell::RefCell;
    use std::rc::Rc;

    fn with_exec(f: impl FnOnce(&mut Executor)) {
        let mut exec = Executor::with_stack_size(64 * 1024);
        let _guard = exec.install();
        f(&mut exec);
    }

    #[test]
    fn spawn_and_complete() {
        with_exec(|exec| {
            let hit = Rc::new(Cell::new(false));
            let h = hit.clone();
            exec.spawn(move || h.set(true));
            assert_eq!(exec.live(), 1);
            assert!(exec.run_one());
            assert!(hit.get());
            assert_eq!(exec.live(), 0);
            assert!(!exec.run_one());
        });
    }

    #[test]
    fn yield_round_robin() {
        with_exec(|exec| {
            let order = Rc::new(RefCell::new(Vec::new()));
            for tag in 0..3 {
                let o = order.clone();
                exec.spawn(move || {
                    o.borrow_mut().push((tag, 0));
                    yield_now();
                    o.borrow_mut().push((tag, 1));
                });
            }
            exec.run_until_idle();
            let got = order.borrow().clone();
            assert_eq!(
                got,
                vec![(0, 0), (1, 0), (2, 0), (0, 1), (1, 1), (2, 1)],
                "fibers should interleave FIFO"
            );
        });
    }

    #[test]
    fn suspend_and_resume() {
        with_exec(|exec| {
            let parked: Rc<Cell<Option<FiberId>>> = Rc::new(Cell::new(None));
            let p = parked.clone();
            let steps = Rc::new(Cell::new(0));
            let s = steps.clone();
            exec.spawn(move || {
                s.set(1);
                suspend(|id| p.set(Some(id)));
                s.set(2);
            });
            exec.run_until_idle();
            assert_eq!(steps.get(), 1, "fiber parked after step 1");
            assert_eq!(exec.live(), 1);
            let id = parked.get().expect("stash ran");
            assert_eq!(exec.state(id), Some(State::Parked));
            exec.resume(id);
            exec.run_until_idle();
            assert_eq!(steps.get(), 2);
            assert_eq!(exec.live(), 0);
        });
    }

    #[test]
    fn resume_if_parked_is_safe_on_any_id() {
        with_exec(|exec| {
            let parked: Rc<Cell<Option<FiberId>>> = Rc::new(Cell::new(None));
            let p = parked.clone();
            exec.spawn(move || suspend(|id| p.set(Some(id))));
            exec.run_until_idle();
            let id = parked.get().unwrap();
            assert_eq!(exec.parked(), 1);
            assert!(exec.resume_if_parked(id), "parked fiber resumes");
            assert!(!exec.resume_if_parked(id), "already ready: no-op");
            exec.run_until_idle();
            assert!(!exec.resume_if_parked(id), "completed fiber: no-op");
            assert!(!exec.resume_if_parked(9999), "unknown id: no-op");
            assert_eq!(exec.parked(), 0);
        });
    }

    #[test]
    fn fiber_spawns_fiber() {
        with_exec(|exec| {
            let hits = Rc::new(Cell::new(0));
            let h = hits.clone();
            exec.spawn(move || {
                let h2 = h.clone();
                // Spawning from inside a fiber goes through TLS.
                with_executor(|e| e.spawn(move || h2.set(h2.get() + 10)));
                h.set(h.get() + 1);
            });
            exec.run_until_idle();
            assert_eq!(hits.get(), 11);
        });
    }

    #[test]
    fn many_fibers() {
        with_exec(|exec| {
            let sum = Rc::new(Cell::new(0u64));
            for i in 0..500u64 {
                let s = sum.clone();
                exec.spawn(move || {
                    yield_now();
                    s.set(s.get() + i);
                });
            }
            exec.run_until_idle();
            assert_eq!(sum.get(), 500 * 499 / 2);
            assert_eq!(exec.live(), 0);
        });
    }

    #[test]
    fn deep_stack_usage() {
        with_exec(|exec| {
            let ok = Rc::new(Cell::new(false));
            let o = ok.clone();
            exec.spawn(move || {
                // Recurse enough to use a few KB of fiber stack.
                fn rec(n: u64) -> u64 {
                    let pad = [n; 16]; // force frame growth
                    if n == 0 {
                        pad[0]
                    } else {
                        rec(n - 1) + pad[15] % 2
                    }
                }
                let v = rec(200);
                o.set(v < 1000);
            });
            exec.run_until_idle();
            assert!(ok.get());
        });
    }

    #[test]
    fn panic_propagates_to_scheduler() {
        let result = std::panic::catch_unwind(|| {
            with_exec(|exec| {
                exec.spawn(|| panic!("boom in fiber"));
                exec.run_until_idle();
            });
        });
        let err = result.expect_err("panic should propagate");
        let msg = err.downcast_ref::<&str>().copied().unwrap_or("");
        assert_eq!(msg, "boom in fiber");
    }

    #[test]
    fn panic_does_not_poison_other_fibers() {
        with_exec(|exec| {
            let hit = Rc::new(Cell::new(false));
            let h = hit.clone();
            exec.spawn(|| panic!("first dies"));
            exec.spawn(move || h.set(true));
            let _ = std::panic::catch_unwind(AssertUnwindSafe(|| {
                exec.run_until_idle();
            }));
            // Second fiber still runnable after the first one's panic.
            exec.run_until_idle();
            assert!(hit.get());
            assert_eq!(exec.live(), 0);
        });
    }

    #[test]
    fn in_fiber_and_current_reporting() {
        with_exec(|exec| {
            assert!(!in_fiber());
            let seen = Rc::new(Cell::new(false));
            let s = seen.clone();
            exec.spawn(move || {
                s.set(in_fiber() && current_fiber().is_some());
            });
            exec.run_until_idle();
            assert!(seen.get());
            assert!(!in_fiber());
        });
    }

    #[test]
    fn stacks_are_recycled() {
        with_exec(|exec| {
            for _ in 0..10 {
                exec.spawn(|| {});
            }
            exec.run_until_idle();
            assert!(exec.pooled_stacks() >= 1, "stacks returned to pool");
            assert_eq!(exec.spawned_total, 10);
        });
    }

    #[test]
    fn ids_are_reused() {
        with_exec(|exec| {
            let a = exec.spawn(|| {});
            exec.run_until_idle();
            let b = exec.spawn(|| {});
            exec.run_until_idle();
            assert_eq!(a, b, "slab id should be recycled");
        });
    }
}
