//! The x86-64 SysV context switch at the heart of the fiber runtime.
//!
//! A *context* is just a saved stack pointer; everything else (the six
//! callee-saved registers) lives on the stack it points to. Switching is
//! ~12 instructions and touches one cache line of each stack — this is what
//! makes `apply()`'s suspend/resume cheap enough for the paper's
//! fiber-per-request model (§3.3).
//!
//! Safety model: fibers never migrate between OS threads, so a context is
//! only ever switched from the thread that created it. Panics never unwind
//! across a switch (the fiber entry wraps user code in `catch_unwind`).

#[cfg(not(target_arch = "x86_64"))]
compile_error!("the fiber runtime implements x86-64 SysV context switching only");

/// A saved execution context (stack pointer into a stack holding the
/// callee-saved registers and a return address).
#[derive(Debug)]
#[repr(C)]
pub struct Context {
    pub(crate) rsp: *mut u8,
}

impl Context {
    /// A context that must be written (by a switch *away* from it) before
    /// it is ever restored.
    pub fn empty() -> Context {
        Context { rsp: std::ptr::null_mut() }
    }
}

/// Switch from the current context to `restore_rsp`, saving the current
/// context's stack pointer through `save`.
///
/// # Safety
/// - `restore_rsp` must be a stack pointer previously produced by this
///   function (or by [`prepare_stack`]) on the **same OS thread**.
/// - The stack behind `restore_rsp` must be live and not in use by any
///   other execution.
#[unsafe(naked)]
pub unsafe extern "sysv64" fn raw_switch(save: *mut *mut u8, restore_rsp: *mut u8) {
    core::arch::naked_asm!(
        // Save callee-saved registers on the current stack.
        "push rbp",
        "push rbx",
        "push r12",
        "push r13",
        "push r14",
        "push r15",
        // Publish the old stack pointer, adopt the new one.
        "mov [rdi], rsp",
        "mov rsp, rsi",
        // Restore the target's callee-saved registers and return into it.
        "pop r15",
        "pop r14",
        "pop r13",
        "pop r12",
        "pop rbx",
        "pop rbp",
        "ret",
    )
}

/// First-run trampoline: a brand-new fiber's prepared stack "returns" here.
/// The fiber pointer was parked in `rbx` by [`prepare_stack`]; move it into
/// the first argument register and enter the Rust entry point.
// SAFETY: naked — the asm below is the whole body; entered only by the
// `ret` in raw_switch from a stack laid out by prepare_stack (fiber
// pointer parked in rbx), and fiber_entry never returns.
#[unsafe(naked)]
unsafe extern "sysv64" fn fiber_trampoline() {
    core::arch::naked_asm!(
        "mov rdi, rbx",
        "call {entry}",
        // The entry point never returns; trap if it somehow does.
        "ud2",
        entry = sym super::fiber_entry,
    )
}

/// Prepare a fresh stack so that switching to the returned rsp enters
/// [`fiber_trampoline`] with `fiber_ptr` in `rbx`.
///
/// Layout (addresses descending from `top`, which must be 16-aligned):
/// ```text
///   top-8  : fiber_trampoline        <- 'ret' target
///   top-16 : rbp = 0
///   top-24 : rbx = fiber_ptr
///   top-32 : r12 = 0
///   top-40 : r13 = 0
///   top-48 : r14 = 0
///   top-56 : r15 = 0                 <- returned rsp
/// ```
/// After the six pops and the `ret`, rsp = `top`, which is 16-aligned, so
/// the `call` in the trampoline gives the entry function a correctly
/// aligned frame (rsp ≡ 8 mod 16 at entry, per the SysV ABI).
///
/// # Safety
/// `top` must be 16-aligned with at least 56 writable bytes below it;
/// `fiber_ptr` is stored opaquely and handed to `fiber_entry` later.
pub unsafe fn prepare_stack(top: *mut u8, fiber_ptr: *mut u8) -> *mut u8 {
    debug_assert_eq!(top as usize % 16, 0, "stack top must be 16-aligned");
    let mut p = top as *mut u64;
    // SAFETY: caller guarantees at least 56 writable bytes below `top`.
    unsafe {
        p = p.sub(1);
        p.write(fiber_trampoline as *const () as usize as u64); // ret target
        p = p.sub(1);
        p.write(0); // rbp
        p = p.sub(1);
        p.write(fiber_ptr as u64); // rbx
        p = p.sub(1);
        p.write(0); // r12
        p = p.sub(1);
        p.write(0); // r13
        p = p.sub(1);
        p.write(0); // r14
        p = p.sub(1);
        p.write(0); // r15
    }
    p as *mut u8
}
