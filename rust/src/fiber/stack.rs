//! Fiber stacks: `mmap`-allocated with a PROT_NONE guard page, recycled
//! through a per-thread pool (stack allocation is on the `launch()` hot
//! path — §4.3 creates a temporary fiber per launched closure).

use crate::util::sys as libc;
use std::ptr::NonNull;

/// Default usable stack size. Virtual memory only — pages are faulted in
/// lazily, so a generous default costs little.
pub const DEFAULT_STACK_SIZE: usize = 256 * 1024;

fn page_size() -> usize {
    // SAFETY: sysconf is always safe to call.
    let sz = unsafe { libc::sysconf(libc::_SC_PAGESIZE) };
    if sz <= 0 {
        4096
    } else {
        sz as usize
    }
}

/// An owned, guard-paged fiber stack.
pub struct Stack {
    /// Base of the mapping (the guard page).
    base: NonNull<u8>,
    /// Total mapping length including the guard page.
    len: usize,
}

// The stack is plain memory; ownership moves with the Fiber.
unsafe impl Send for Stack {}

impl Stack {
    /// Allocate a stack with at least `usable` usable bytes plus one guard
    /// page at the low end (overflow faults instead of corrupting memory).
    pub fn new(usable: usize) -> Stack {
        let page = page_size();
        let usable = usable.div_ceil(page) * page;
        let len = usable + page;
        // SAFETY: anonymous private mapping; checked for MAP_FAILED below.
        let base = unsafe {
            libc::mmap(
                std::ptr::null_mut(),
                len,
                libc::PROT_READ | libc::PROT_WRITE,
                libc::MAP_PRIVATE | libc::MAP_ANONYMOUS | libc::MAP_STACK,
                -1,
                0,
            )
        };
        assert!(base != libc::MAP_FAILED, "mmap fiber stack failed");
        // SAFETY: base is a fresh page-aligned mapping of >= 1 page.
        unsafe {
            let r = libc::mprotect(base, page, libc::PROT_NONE);
            assert_eq!(r, 0, "mprotect guard page failed");
        }
        Stack {
            base: NonNull::new(base as *mut u8).unwrap(),
            len,
        }
    }

    /// Highest address of the stack (stacks grow down), 16-byte aligned.
    pub fn top(&self) -> *mut u8 {
        let top = unsafe { self.base.as_ptr().add(self.len) };
        ((top as usize) & !15) as *mut u8
    }

    /// Usable bytes (excludes guard page).
    pub fn usable(&self) -> usize {
        self.len - page_size()
    }
}

impl Drop for Stack {
    fn drop(&mut self) {
        // SAFETY: we own the whole mapping.
        unsafe {
            libc::munmap(self.base.as_ptr() as *mut libc::c_void, self.len);
        }
    }
}

/// Per-thread stack pool: `launch()` churn reuses warm stacks instead of
/// paying mmap/munmap per fiber.
pub struct StackPool {
    free: Vec<Stack>,
    size: usize,
    max_pooled: usize,
}

impl StackPool {
    pub fn new(size: usize, max_pooled: usize) -> StackPool {
        StackPool { free: Vec::new(), size, max_pooled }
    }

    pub fn get(&mut self) -> Stack {
        self.free.pop().unwrap_or_else(|| Stack::new(self.size))
    }

    pub fn put(&mut self, s: Stack) {
        if self.free.len() < self.max_pooled && s.usable() >= self.size {
            self.free.push(s);
        }
    }

    pub fn pooled(&self) -> usize {
        self.free.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stack_alloc_and_use() {
        let s = Stack::new(64 * 1024);
        assert!(s.usable() >= 64 * 1024);
        let top = s.top();
        assert_eq!(top as usize % 16, 0);
        // Touch memory near the top (valid region).
        unsafe {
            let p = top.sub(8);
            p.write(0xAB);
            assert_eq!(p.read(), 0xAB);
        }
    }

    #[test]
    fn pool_reuses() {
        let mut pool = StackPool::new(32 * 1024, 4);
        let a = pool.get();
        let a_top = a.top() as usize;
        pool.put(a);
        assert_eq!(pool.pooled(), 1);
        let b = pool.get();
        assert_eq!(b.top() as usize, a_top, "stack should be reused");
        assert_eq!(pool.pooled(), 0);
    }

    #[test]
    fn pool_caps_retention() {
        let mut pool = StackPool::new(16 * 1024, 2);
        let stacks: Vec<Stack> = (0..4).map(|_| pool.get()).collect();
        for s in stacks {
            pool.put(s);
        }
        assert_eq!(pool.pooled(), 2);
    }
}
