//! Fiber stacks: `mmap`-allocated with a PROT_NONE guard page, recycled
//! through a per-thread pool (stack allocation is on the `launch()` hot
//! path — §4.3 creates a temporary fiber per launched closure).

use crate::util::sys as libc;
use std::ptr::NonNull;

/// Default usable stack size. Virtual memory only — pages are faulted in
/// lazily, so a generous default costs little.
pub const DEFAULT_STACK_SIZE: usize = 256 * 1024;

fn page_size() -> usize {
    // SAFETY: sysconf is always safe to call.
    let sz = unsafe { libc::sysconf(libc::_SC_PAGESIZE) };
    if sz <= 0 {
        4096
    } else {
        sz as usize
    }
}

/// An owned, guard-paged fiber stack.
pub struct Stack {
    /// Base of the mapping (the guard page).
    base: NonNull<u8>,
    /// Total mapping length including the guard page.
    len: usize,
}

// SAFETY: the stack is plain owned memory (mmap'd below); ownership
// moves with the Stack and no aliasing references escape.
unsafe impl Send for Stack {}

impl Stack {
    /// Allocate a stack with at least `usable` usable bytes plus one guard
    /// page at the low end (overflow faults instead of corrupting memory).
    pub fn new(usable: usize) -> Stack {
        let page = page_size();
        let usable = usable.div_ceil(page) * page;
        let len = usable + page;
        // SAFETY: anonymous private mapping; checked for MAP_FAILED below.
        let base = unsafe {
            libc::mmap(
                std::ptr::null_mut(),
                len,
                libc::PROT_READ | libc::PROT_WRITE,
                libc::MAP_PRIVATE | libc::MAP_ANONYMOUS | libc::MAP_STACK,
                -1,
                0,
            )
        };
        assert!(base != libc::MAP_FAILED, "mmap fiber stack failed");
        // SAFETY: base is a fresh page-aligned mapping of >= 1 page.
        unsafe {
            let r = libc::mprotect(base, page, libc::PROT_NONE);
            assert_eq!(r, 0, "mprotect guard page failed");
        }
        Stack {
            base: NonNull::new(base as *mut u8).unwrap(),
            len,
        }
    }

    /// Highest address of the stack (stacks grow down), 16-byte aligned.
    pub fn top(&self) -> *mut u8 {
        // SAFETY: base+len is one-past-the-end of our live mapping — valid for
        // pointer arithmetic; the pointer is only ever used below the top.
        let top = unsafe { self.base.as_ptr().add(self.len) };
        ((top as usize) & !15) as *mut u8
    }

    /// Usable bytes (excludes guard page).
    pub fn usable(&self) -> usize {
        self.len - page_size()
    }
}

impl Drop for Stack {
    fn drop(&mut self) {
        // SAFETY: we own the whole mapping.
        unsafe {
            libc::munmap(self.base.as_ptr() as *mut libc::c_void, self.len);
        }
    }
}

/// Per-thread stack pool: `launch()` churn reuses warm stacks instead of
/// paying mmap/munmap per fiber.
pub struct StackPool {
    free: Vec<Stack>,
    size: usize,
    max_pooled: usize,
}

impl StackPool {
    pub fn new(size: usize, max_pooled: usize) -> StackPool {
        StackPool { free: Vec::new(), size, max_pooled }
    }

    pub fn get(&mut self) -> Stack {
        self.free.pop().unwrap_or_else(|| Stack::new(self.size))
    }

    pub fn put(&mut self, s: Stack) {
        if self.free.len() < self.max_pooled && s.usable() >= self.size {
            self.free.push(s);
        }
    }

    pub fn pooled(&self) -> usize {
        self.free.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stack_alloc_and_use() {
        let s = Stack::new(64 * 1024);
        assert!(s.usable() >= 64 * 1024);
        let top = s.top();
        assert_eq!(top as usize % 16, 0);
        // Touch memory near the top (valid region).
        // SAFETY: top-8 lies inside the usable (non-guard) region of the
        // mapping we just created.
        unsafe {
            let p = top.sub(8);
            p.write(0xAB);
            assert_eq!(p.read(), 0xAB);
        }
    }

    /// Pin the bounds accounting: one guard page below exactly
    /// `usable()` bytes, `top()` 16-aligned at the high end of the
    /// mapping (ISSUE 6 satellite).
    #[test]
    fn stack_bounds_accounting() {
        let page = page_size();
        // Deliberately not a page multiple: must round *up*.
        let s = Stack::new(100 * 1024);
        assert_eq!(s.usable() % page, 0, "usable size is whole pages");
        assert!(s.usable() >= 100 * 1024, "never less than requested");
        assert!(s.usable() < 100 * 1024 + page, "rounds up by less than a page");
        assert_eq!(s.len, s.usable() + page, "exactly one guard page");

        let base = s.base.as_ptr() as usize;
        assert_eq!(base % page, 0, "mmap returns page-aligned memory");
        // The usable region is [base + page, base + len).
        assert_eq!(base + page + s.usable(), base + s.len);

        let top = s.top() as usize;
        assert_eq!(top % 16, 0, "switch code requires a 16-aligned top");
        assert!(top <= base + s.len, "top never exceeds the mapping");
        // base and len are both page multiples, so the mapping end is
        // already 16-aligned and the mask in top() shaves nothing.
        assert_eq!(top, base + s.len, "page-aligned top needs no rounding");
        assert_eq!(top - s.usable(), base + page, "usable region sits above the guard");
    }

    /// The guard page must actually be PROT_NONE in the kernel's view:
    /// find the mapping in /proc/self/maps and check its permission bits
    /// (an overflowing fiber then faults instead of corrupting memory).
    #[test]
    #[cfg(target_os = "linux")]
    fn guard_page_is_prot_none_in_proc_maps() {
        let s = Stack::new(64 * 1024);
        let base = s.base.as_ptr() as usize;
        let page = page_size();
        let maps = std::fs::read_to_string("/proc/self/maps").unwrap();
        let mut guard = None;
        let mut usable = None;
        for line in maps.lines() {
            let Some((range, rest)) = line.split_once(' ') else { continue };
            let Some((lo, hi)) = range.split_once('-') else { continue };
            let lo = usize::from_str_radix(lo, 16).unwrap();
            let hi = usize::from_str_radix(hi, 16).unwrap();
            if lo == base {
                guard = Some((hi, rest[..4].to_string()));
            }
            if lo == base + page {
                usable = Some((hi, rest[..4].to_string()));
            }
        }
        let (ghi, gperms) = guard.expect("guard page VMA missing from /proc/self/maps");
        assert_eq!(ghi, base + page, "guard VMA spans exactly one page");
        assert!(
            gperms.starts_with("---"),
            "guard page must be PROT_NONE, got {gperms}"
        );
        // The kernel may merge the rw region with an adjacent anonymous
        // mapping above it, so only require it to cover our stack.
        let (uhi, uperms) = usable.expect("usable-region VMA missing");
        assert!(uhi >= base + s.len, "usable VMA covers the stack");
        assert!(
            uperms.starts_with("rw-"),
            "usable region must be read-write, got {uperms}"
        );
    }

    #[test]
    fn pool_reuses() {
        let mut pool = StackPool::new(32 * 1024, 4);
        let a = pool.get();
        let a_top = a.top() as usize;
        pool.put(a);
        assert_eq!(pool.pooled(), 1);
        let b = pool.get();
        assert_eq!(b.top() as usize, a_top, "stack should be reused");
        assert_eq!(pool.pooled(), 0);
    }

    #[test]
    fn pool_caps_retention() {
        let mut pool = StackPool::new(16 * 1024, 2);
        let stacks: Vec<Stack> = (0..4).map(|_| pool.get()).collect();
        for s in stacks {
            pool.put(s);
        }
        assert_eq!(pool.pooled(), 2);
    }
}
