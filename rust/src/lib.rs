//! # Trust\<T\>: delegation as a scalable, type- and memory-safe alternative to locks
//!
//! Reproduction of *"Delegation with Trust\<T\>"* (Ahmad, Baenen, Chen,
//! Eriksson, 2024). Instead of synchronizing multi-threaded access to an
//! object of type `T` with a lock, the object is placed in a [`Trust<T>`]
//! and becomes accessible only by *delegating* closures to its *trustee*
//! thread over a shared-memory message-passing channel:
//!
//! ```ignore
//! let rt = trustee::runtime::Runtime::builder().workers(4).build();
//! rt.block_on(0, |cx| {
//!     let ct = cx.local_trustee().entrust(17u64);
//!     ct.apply(|c| *c += 1);
//!     assert_eq!(ct.apply(|c| *c), 18);
//! });
//! ```
//!
//! ## Crate layout (paper section in parentheses)
//!
//! - [`fiber`] — stackful user threads and per-worker scheduler (§3.3, §5.2)
//! - [`channel`] — two-part request/response delegation slots (§5.1, §5.3)
//! - [`trust`] — `Trust<T>`, `apply`/`apply_then`/`apply_with`/`launch`,
//!   `Latch<T>`, delegated reference counting (§3, §4)
//! - [`runtime`] — worker topology (shared / dedicated trustees), the
//!   PJRT/XLA executor for AOT-compiled batch-apply artifacts (§5.2)
//! - [`locks`] — the lock baselines the paper evaluates against (§6)
//! - [`cmap`] — the open-addressing robin-hood table behind every shard
//!   (§6.3)
//! - [`server`] — the protocol-agnostic delegated server core: one
//!   connection engine (ingest, backpressure, both response-ordering
//!   disciplines, drain-on-stop) parameterised by a `Protocol` trait,
//!   plus the RESP (Redis) front end
//! - [`kvstore`] — the TCP key-value store application (§6.3) and the
//!   **unified item store** (`kvstore::store`): one shard type with
//!   flags/TTL/LRU-budget semantics behind all four backends
//! - [`loadgen`] — the shared pipelined-loader skeleton behind all three
//!   protocol load generators
//! - [`memcache`] — mini-memcached on the unified store: lock baselines
//!   vs delegated shards, real `exptime` (§7)
//! - [`bench`] — workload generators and the figure-regeneration harnesses
//! - [`util`], [`codec`] — substrates built from scratch for the offline
//!   environment (PRNG, zipfian sampling, stats, CLI, affinity, a
//!   property-test harness, and a bincode-style wire codec)
//!
//! See `rust/DESIGN.md` for the full system inventory — including the
//! adaptive flush policy and its FIFO/refcount ordering contracts — and
//! `rust/EXPERIMENTS.md` for the experiment index and measured-vs-paper
//! results.

// Every unsafe operation must sit in an explicit `unsafe {}` block with
// its own SAFETY justification, even inside `unsafe fn` bodies. Enforced
// together with `tests/unsafe_audit.rs` (which requires the comment).
#![deny(unsafe_op_in_unsafe_fn)]

pub mod util;
pub mod codec;
pub mod fiber;
pub mod channel;
pub mod trust;
pub mod runtime;
pub mod locks;
pub mod cmap;
pub mod server;
pub mod kvstore;
pub mod loadgen;
pub mod memcache;
pub mod bench;
#[cfg(feature = "model")]
pub mod model;

pub use trust::{Latch, Trust, TrusteeRef};
