//! The shared pipelined-loader skeleton (ROADMAP item: client-side dedup).
//!
//! `kvstore::client`, `memcache::memtier`, and `server::resp_load` were
//! three near-identical copies of the same per-connection loop:
//! connect + nonblocking preamble, `fail!`-style error macro with
//! progress context, pipeline top-up, partial-write flush, read drain,
//! and in-order/by-id reply parsing. [`run_pipelined_loader`] owns that
//! loop once — the client-side mirror of the `server::engine` refactor —
//! parameterised by a [`LoadDriver`] that encodes requests and parses
//! replies in its own wire format.
//!
//! The skeleton guarantees the loaders' shared error contract: every I/O
//! failure or protocol desync comes back as a **descriptive
//! [`LoaderResult::error`]** carrying `after <done>/<ops> ops:` progress
//! context (never a panic), and operations completed before the failure
//! still count.

use std::io::{ErrorKind, Read, Write};
use std::net::{SocketAddr, TcpStream};

/// One parsed reply: how many bytes it consumed from the receive buffer
/// and whether it counts as a hit (protocol-defined; writes usually
/// report `hit = true`).
pub struct Reply {
    pub used: usize,
    pub hit: bool,
    /// The server answered with its protocol's overload-shed error
    /// (`-BUSY` / `SERVER_ERROR busy` / `ST_OVERLOADED`): the request was
    /// *not* executed but the connection is still good. Not a desync.
    pub shed: bool,
}

impl Reply {
    /// An ordinary (non-shed) reply.
    pub fn ok(used: usize, hit: bool) -> Reply {
        Reply { used, hit, shed: false }
    }

    /// A shed reply (counts neither hit nor miss).
    pub fn shed(used: usize) -> Reply {
        Reply { used, hit: false, shed: true }
    }
}

/// A wire protocol plugged into [`run_pipelined_loader`]. Implementations
/// keep their own per-connection state (RNG, key distribution, id→issue
/// time maps, in-order expectation queues, latency histograms).
pub trait LoadDriver {
    /// Append the next request's bytes to `out` and record whatever
    /// bookkeeping its reply will need. Called while the pipeline has
    /// room; exactly one reply must eventually answer it.
    fn encode_next(&mut self, out: &mut Vec<u8>);

    /// Parse one complete reply from the front of `buf`:
    /// `Ok(Some(reply))` consumes `reply.used` bytes, `Ok(None)` waits
    /// for more bytes, `Err` reports a protocol desync (ends the run
    /// descriptively).
    fn parse_reply(&mut self, buf: &[u8]) -> Result<Option<Reply>, String>;
}

/// Outcome of one connection's run. `error` is `None` when all `ops`
/// completed; otherwise it carries the failure with progress context and
/// `done`/`hits`/`misses` report the work finished before it.
pub struct LoaderResult {
    pub done: u64,
    pub hits: u64,
    pub misses: u64,
    /// Replies the server shed with an overload error (counted toward
    /// `done` only when the retry budget ran out or retry was off).
    pub shed: u64,
    pub error: Option<String>,
}

/// [`run_pipelined_loader_opts`] with shed-retry off: a shed reply counts
/// as a completed (non-hit, non-miss) op.
pub fn run_pipelined_loader<D: LoadDriver>(
    addr: SocketAddr,
    pipeline: usize,
    ops: u64,
    driver: &mut D,
) -> LoaderResult {
    run_pipelined_loader_opts(addr, pipeline, ops, driver, false)
}

/// Drive one nonblocking connection until `ops` requests completed (or a
/// failure ends the run): top up a `pipeline`-deep window via
/// [`LoadDriver::encode_next`], flush partial writes, drain the socket,
/// and parse replies via [`LoadDriver::parse_reply`].
///
/// A [`Reply::shed`] reply bumps `shed`; with `retry_shed` it is re-issued
/// through `encode_next` (bounded: at most `ops` total retries, so a
/// permanently-overloaded server still terminates), otherwise it counts
/// as a completed op with no hit/miss.
pub fn run_pipelined_loader_opts<D: LoadDriver>(
    addr: SocketAddr,
    pipeline: usize,
    ops: u64,
    driver: &mut D,
    retry_shed: bool,
) -> LoaderResult {
    let (mut sent, mut done, mut hits, mut misses, mut shed) = (0u64, 0u64, 0u64, 0u64, 0u64);
    let mut inflight = 0usize;
    let mut retry_budget = if retry_shed { ops } else { 0 };

    // One macro instead of `.unwrap()`: bail out with the stats gathered
    // so far and a message carrying progress context.
    macro_rules! fail {
        ($($arg:tt)*) => {
            return LoaderResult {
                done,
                hits,
                misses,
                shed,
                error: Some(format!("after {done}/{ops} ops: {}", format!($($arg)*))),
            }
        };
    }

    let mut stream = match TcpStream::connect(addr) {
        Ok(s) => s,
        Err(e) => fail!("connect {addr}: {e}"),
    };
    stream.set_nodelay(true).ok();
    if let Err(e) = stream.set_nonblocking(true) {
        fail!("nonblocking: {e}");
    }

    let mut out: Vec<u8> = Vec::with_capacity(64 * 1024);
    let mut wcur = 0usize;
    let mut inbuf: Vec<u8> = Vec::with_capacity(64 * 1024);
    let mut parsed = 0usize; // consumed prefix of inbuf

    while done < ops {
        // Top up the pipeline.
        while sent < ops && inflight < pipeline {
            driver.encode_next(&mut out);
            sent += 1;
            inflight += 1;
        }
        // Flush writes (partial ok).
        loop {
            if wcur >= out.len() {
                out.clear();
                wcur = 0;
                break;
            }
            match stream.write(&out[wcur..]) {
                Ok(0) => fail!("server closed connection mid-write"),
                Ok(n) => wcur += n,
                Err(e) if e.kind() == ErrorKind::WouldBlock => break,
                Err(e) if e.kind() == ErrorKind::Interrupted => {}
                Err(e) => fail!("write: {e}"),
            }
        }
        // Drain the socket.
        let mut chunk = [0u8; 32 * 1024];
        match stream.read(&mut chunk) {
            Ok(0) => fail!("server closed connection mid-run"),
            Ok(n) => inbuf.extend_from_slice(&chunk[..n]),
            Err(e) if e.kind() == ErrorKind::WouldBlock => {}
            Err(e) if e.kind() == ErrorKind::Interrupted => {}
            Err(e) => fail!("read: {e}"),
        }
        // Parse replies.
        while inflight > 0 {
            match driver.parse_reply(&inbuf[parsed..]) {
                Ok(Some(reply)) => {
                    parsed += reply.used;
                    inflight -= 1;
                    if reply.shed {
                        shed += 1;
                        if retry_budget > 0 {
                            // Re-issue through the normal top-up path (the
                            // driver books fresh expectation state there).
                            retry_budget -= 1;
                            sent -= 1;
                            continue;
                        }
                        // Out of retries (or retry off): a counted,
                        // valueless completion.
                        done += 1;
                        continue;
                    }
                    done += 1;
                    if reply.hit {
                        hits += 1;
                    } else {
                        misses += 1;
                    }
                }
                Ok(None) => break,
                Err(e) => fail!("{e}"),
            }
        }
        if parsed > 0 {
            inbuf.drain(..parsed);
            parsed = 0;
        }
    }
    LoaderResult { done, hits, misses, shed, error: None }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Line-echo driver over a trivial protocol: request "ping\n",
    /// reply "pong\n" (hit) or "miss\n".
    struct EchoDriver {
        sent: u64,
    }

    impl LoadDriver for EchoDriver {
        fn encode_next(&mut self, out: &mut Vec<u8>) {
            self.sent += 1;
            out.extend_from_slice(b"ping\n");
        }

        fn parse_reply(&mut self, buf: &[u8]) -> Result<Option<Reply>, String> {
            let Some(nl) = buf.iter().position(|&b| b == b'\n') else {
                return Ok(None);
            };
            match &buf[..nl] {
                b"pong" => Ok(Some(Reply::ok(nl + 1, true))),
                b"miss" => Ok(Some(Reply::ok(nl + 1, false))),
                b"busy" => Ok(Some(Reply::shed(nl + 1))),
                other => Err(format!(
                    "unexpected reply {:?}",
                    String::from_utf8_lossy(other)
                )),
            }
        }
    }

    fn echo_server(
        replies: &'static [u8],
    ) -> (SocketAddr, std::thread::JoinHandle<()>) {
        let listener = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let h = std::thread::spawn(move || {
            let (mut s, _) = listener.accept().unwrap();
            let mut buf = [0u8; 4096];
            let mut served = 0usize;
            loop {
                let n = match s.read(&mut buf) {
                    Ok(0) | Err(_) => return,
                    Ok(n) => n,
                };
                for _ in buf[..n].iter().filter(|&&b| b == b'\n') {
                    let reply = &replies[(served % (replies.len() / 5)) * 5..][..5];
                    if s.write_all(reply).is_err() {
                        return;
                    }
                    served += 1;
                }
            }
        });
        (addr, h)
    }

    #[test]
    fn loader_completes_and_counts_hits_and_misses() {
        // Server alternates pong/miss; 10 ops → 5 hits, 5 misses.
        let (addr, h) = echo_server(b"pong\nmiss\n");
        let mut driver = EchoDriver { sent: 0 };
        let r = run_pipelined_loader(addr, 4, 10, &mut driver);
        assert!(r.error.is_none(), "{:?}", r.error);
        assert_eq!((r.done, r.hits, r.misses), (10, 5, 5));
        assert_eq!(driver.sent, 10);
        drop(h);
    }

    #[test]
    fn shed_replies_count_without_retry() {
        // Server alternates pong/busy; without retry a shed reply is a
        // completed op that is neither hit nor miss.
        let (addr, h) = echo_server(b"pong\nbusy\n");
        let mut driver = EchoDriver { sent: 0 };
        let r = run_pipelined_loader(addr, 4, 10, &mut driver);
        assert!(r.error.is_none(), "{:?}", r.error);
        assert_eq!((r.done, r.hits, r.misses, r.shed), (10, 5, 0, 5));
        assert_eq!(driver.sent, 10);
        drop(h);
    }

    #[test]
    fn shed_replies_reissue_with_retry() {
        // pong/pong/busy rotation: every third reply is shed and retried.
        // 12 completions require 12 pongs; the retry budget (= ops) is
        // ample, so every done op is a hit and shed counts the retries.
        let (addr, h) = echo_server(b"pong\npong\nbusy\n");
        let mut driver = EchoDriver { sent: 0 };
        let r = run_pipelined_loader_opts(addr, 4, 12, &mut driver, true);
        assert!(r.error.is_none(), "{:?}", r.error);
        assert_eq!((r.done, r.hits, r.misses), (12, 12, 0));
        assert!(r.shed >= 4, "rotation sheds every 3rd reply: {}", r.shed);
        assert_eq!(driver.sent as u64, 12 + r.shed);
        drop(h);
    }

    #[test]
    fn loader_connect_failure_has_progress_context() {
        let mut driver = EchoDriver { sent: 0 };
        let r = run_pipelined_loader("127.0.0.1:1".parse().unwrap(), 4, 10, &mut driver);
        let e = r.error.expect("must fail");
        assert!(e.contains("connect"), "unhelpful: {e}");
        assert!(e.contains("0/10 ops"), "missing progress context: {e}");
        assert_eq!(r.done, 0);
    }

    #[test]
    fn loader_desync_reports_driver_error() {
        let (addr, h) = echo_server(b"what\nwhat\n");
        let mut driver = EchoDriver { sent: 0 };
        let r = run_pipelined_loader(addr, 2, 4, &mut driver);
        let e = r.error.expect("desync must fail");
        assert!(e.contains("unexpected reply"), "unhelpful: {e}");
        drop(h);
    }
}
