//! E23 — io_uring data plane vs readiness plane: throughput and
//! syscalls/op for the same pipelined GET workload under
//! `NetPolicy::IoUring`, A/B'd inside one process via the data-plane
//! kill switch ([`trustee::runtime::uring::set_dataplane_enabled`];
//! servers started after the flip observe it).
//!
//! The readiness cell is PR 8's plane: parked fibers woken by ring
//! polls, then `read()`/`write()` per wake. The data cell is this PR's
//! plane: multishot RECV into provided buffers and ring-submitted SEND,
//! so a registered connection's steady state makes **zero** read/write
//! syscalls — the bench asserts exactly that via the server-side syscall
//! counters, plus the buffer-recycling invariant (`pbuf_recycled` ≈
//! RECV completions that carried a buffer).
//!
//! Usage: cargo bench --bench uring_dataplane -- \
//!          [--ops N] [--conns N] [--pipeline N] [--json]
//!
//! `--json` emits one machine-readable object (captured by
//! `scripts/bench_smoke.sh` as `BENCH_uring_dataplane.json`). On kernels
//! without io_uring or without `IORING_REGISTER_PBUF_RING` the missing
//! cells are skipped with a visible note and the bench still exits 0.

use std::io::{Read, Write};
use std::net::TcpStream;
use trustee::bench::print_table;
use trustee::kvstore::{proto, BackendKind, KvServer, KvServerConfig, NetPolicy};
use trustee::runtime::uring::{self, UringStats};
use trustee::server::netfiber;
use trustee::util::cli::Args;
use trustee::util::stats::fmt_ns;

/// One pipelined burst: `depth` GETs written back to back, then all
/// `depth` responses drained. Returns bytes of value payload observed
/// (a cheap correctness signal: prefilled values are 16 bytes).
fn burst(c: &mut TcpStream, rbuf: &mut Vec<u8>, chunk: &mut [u8], id: u64, depth: u64) -> usize {
    let mut wbuf = Vec::new();
    for k in 0..depth {
        let key = trustee::kvstore::key_bytes((id + k) % 64);
        proto::write_request(&mut wbuf, id + k, proto::OP_GET, &key, &[]);
    }
    c.write_all(&wbuf).unwrap();
    rbuf.clear();
    let mut cursor = proto::FrameCursor::new();
    let mut got = 0;
    let mut val_bytes = 0;
    while got < depth {
        if let Some(r) = cursor.next_response(rbuf).unwrap() {
            assert_eq!(r.status, proto::ST_OK, "prefilled GET must hit");
            val_bytes += r.val.len();
            got += 1;
            continue;
        }
        let n = c.read(chunk).unwrap();
        assert!(n > 0, "server closed mid-burst");
        rbuf.extend_from_slice(&chunk[..n]);
    }
    val_bytes
}

struct Cell {
    plane: &'static str,
    ops: u64,
    ops_per_sec: f64,
    per_op_ns: f64,
    /// Server-side `read()`/`write()` syscalls per op (netfiber counters;
    /// this bench is the only traffic in the process, so deltas are
    /// attributable).
    reads_per_op: f64,
    writes_per_op: f64,
    uring: UringStats,
}

fn run_cell(dataplane: bool, conns: usize, ops: u64, depth: u64) -> Cell {
    uring::set_dataplane_enabled(dataplane);
    let server = KvServer::start(KvServerConfig {
        workers: 2,
        backend: BackendKind::Trust { shards: 2 },
        net: NetPolicy::IoUring,
        ..Default::default()
    });
    server.prefill(64, 16);
    let mut pool: Vec<TcpStream> = (0..conns)
        .map(|_| {
            let c = TcpStream::connect(server.addr()).unwrap();
            c.set_nodelay(true).ok();
            c
        })
        .collect();
    let mut rbuf = Vec::new();
    let mut chunk = [0u8; 64 * 1024];
    let bursts = ops / depth;
    let warmup = (bursts / 10).max(4);
    for i in 0..warmup {
        let c = &mut pool[(i as usize) % conns];
        burst(c, &mut rbuf, &mut chunk, i * depth, depth);
    }
    let reads0 = netfiber::read_syscalls();
    let writes0 = netfiber::write_syscalls();
    let stats0 = server.uring_stats();
    let t0 = std::time::Instant::now();
    for i in 0..bursts {
        let c = &mut pool[(i as usize) % conns];
        burst(c, &mut rbuf, &mut chunk, (1u64 << 32) | (i * depth), depth);
    }
    let elapsed = t0.elapsed().as_secs_f64();
    let done = bursts * depth;
    let reads = netfiber::read_syscalls() - reads0;
    let writes = netfiber::write_syscalls() - writes0;
    let mut stats = server.uring_stats();
    drop(pool);
    server.stop();
    // Report the measured window's deltas, not process totals (the two
    // cells share one process).
    stats.enters -= stats0.enters;
    stats.sqes_submitted -= stats0.sqes_submitted;
    stats.cqes_harvested -= stats0.cqes_harvested;
    stats.recv_cqes -= stats0.recv_cqes;
    stats.pbuf_recycled -= stats0.pbuf_recycled;
    stats.enobufs -= stats0.enobufs;
    stats.send_sqes -= stats0.send_sqes;
    stats.short_send_continuations -= stats0.short_send_continuations;
    Cell {
        plane: if dataplane { "data (pbuf+multishot)" } else { "readiness (poll+read)" },
        ops: done,
        ops_per_sec: done as f64 / elapsed,
        per_op_ns: elapsed / done as f64 * 1e9,
        reads_per_op: reads as f64 / done as f64,
        writes_per_op: writes as f64 / done as f64,
        uring: stats,
    }
}

fn json_cell(c: &Cell) -> String {
    format!(
        "{{\"plane\":\"{}\",\"ops\":{},\"ops_per_sec\":{:.0},\"per_op_ns\":{:.1},\
         \"read_syscalls_per_op\":{:.4},\"write_syscalls_per_op\":{:.4},\
         \"uring_enters\":{},\"uring_sqes\":{},\"uring_cqes\":{},\
         \"recv_cqes\":{},\"pbuf_recycled\":{},\"enobufs\":{},\
         \"send_sqes\":{},\"short_send_continuations\":{}}}",
        c.plane,
        c.ops,
        c.ops_per_sec,
        c.per_op_ns,
        c.reads_per_op,
        c.writes_per_op,
        c.uring.enters,
        c.uring.sqes_submitted,
        c.uring.cqes_harvested,
        c.uring.recv_cqes,
        c.uring.pbuf_recycled,
        c.uring.enobufs,
        c.uring.send_sqes,
        c.uring.short_send_continuations,
    )
}

fn main() {
    let args = Args::from_env();
    let json = args.flag("json");
    let ops: u64 = args.get("ops", 40_000);
    let conns: usize = args.get("conns", 4);
    let depth: u64 = args.get("pipeline", 16);

    if let Err(e) = uring::probe() {
        if json {
            println!("{{\"bench\":\"uring_dataplane\",\"skipped\":\"io_uring unavailable: {e}\"}}");
        } else {
            eprintln!("SKIP uring_dataplane: io_uring unavailable ({e})");
        }
        return;
    }
    let pbuf = match uring::probe_pbuf() {
        Ok(()) => true,
        Err(e) => {
            eprintln!("note: PBUF_RING unavailable ({e}); running the readiness cell only");
            false
        }
    };
    let orig = uring::dataplane_enabled();
    if !orig {
        eprintln!("note: data plane disabled by kill switch (TRUSTEE_URING_NO_PBUF)");
    }

    let readiness = run_cell(false, conns, ops, depth);
    let data = if pbuf && orig { Some(run_cell(true, conns, ops, depth)) } else { None };
    uring::set_dataplane_enabled(orig);

    if let Some(d) = &data {
        // Mechanism invariants — these must hold wherever the plane runs,
        // independent of machine speed (throughput is reported, not
        // asserted, to keep CI runners honest but green).
        assert!(d.uring.recv_cqes > 0, "data cell never saw a RECV CQE: {:?}", d.uring);
        assert!(d.uring.send_sqes > 0, "data cell never staged a SEND SQE: {:?}", d.uring);
        assert_eq!(
            (d.reads_per_op, d.writes_per_op),
            (0.0, 0.0),
            "registered data-plane connections must make no read/write syscalls"
        );
        // Every consumed buffer comes back: the only RECV CQEs that carry
        // no buffer are EOF/ENOBUFS/disarm edges, a handful per
        // connection, so the gap must stay a small constant — a widening
        // gap is a pool leak.
        let gap = d.uring.recv_cqes - d.uring.pbuf_recycled;
        assert!(
            gap <= d.uring.enobufs + (conns as u64) * 4 + 64,
            "provided-buffer leak: {} RECV CQEs vs {} recycled ({:?})",
            d.uring.recv_cqes,
            d.uring.pbuf_recycled,
            d.uring
        );
    }

    if json {
        let mut cells = vec![json_cell(&readiness)];
        cells.extend(data.as_ref().map(json_cell));
        println!(
            "{{\"bench\":\"uring_dataplane\",\"conns\":{conns},\"pipeline\":{depth},\
             \"pbuf_capable\":{pbuf},\"cells\":[{}]}}",
            cells.join(",")
        );
        return;
    }

    let mut rows = Vec::new();
    for c in std::iter::once(&readiness).chain(data.as_ref()) {
        rows.push(vec![
            c.plane.into(),
            format!("{:.0}", c.ops_per_sec),
            fmt_ns(c.per_op_ns),
            format!("{:.3} rd / {:.3} wr", c.reads_per_op, c.writes_per_op),
            format!(
                "{} recv-cqe, {} recycled, {} enobufs, {} send-sqe",
                c.uring.recv_cqes, c.uring.pbuf_recycled, c.uring.enobufs, c.uring.send_sqes
            ),
        ]);
    }
    print_table(
        &format!(
            "E23: io_uring readiness vs data plane \
             ({conns} conns, pipeline {depth}, {ops} GETs per cell)"
        ),
        &["plane", "ops/s", "per-op", "syscalls/op", "data-plane counters"],
        &rows,
    );
    if let Some(d) = &data {
        println!(
            "data/readiness throughput ratio = {:.2}x (expect >= 1.0 on pbuf-capable kernels)",
            d.ops_per_sec / readiness.ops_per_sec
        );
    } else {
        println!("data plane not run (kernel or kill switch); readiness cell only");
    }
}
