//! Figure 8a/8b: key-value store throughput vs. table size (5% writes).
//!
//! Series: TrustD (dedicated trustees, the paper's Trust16/Trust24 scaled
//! to this box), TrustS (shared), Dashmap-like (64-shard RwLock), sharded Mutex,
//! sharded RwLock.
//!
//! Usage: cargo bench --bench fig8_kv_table_size -- \
//!            [--dist uniform|zipf] [--sizes 1,10,...] [--write-pct 5] [--quick]

use trustee::bench::print_table;
use trustee::kvstore::{run_load, BackendKind, KvServer, KvServerConfig, LoadConfig};
use trustee::util::cli::Args;

fn run_one(
    backend: BackendKind,
    dedicated: usize,
    keys: u64,
    dist: &str,
    write_pct: u32,
    ops: u64,
    client_threads: usize,
) -> f64 {
    let server = KvServer::start(KvServerConfig {
        workers: 4,
        dedicated,
        backend,
        addr: "127.0.0.1:0".into(),
        ..Default::default()
    });
    server.prefill(keys, 16);
    let stats = run_load(&LoadConfig {
        addr: server.addr(),
        threads: client_threads,
        pipeline: 32,
        ops_per_thread: ops,
        keys,
        dist: dist.into(),
        write_pct,
        val_len: 16,
        seed: 0xF18,
        retry_shed: false,
    });
    let tput = stats.throughput();
    server.stop();
    tput
}

fn main() {
    let args = Args::from_env();
    let dist_arg = args.get_str("dist", "both");
    let quick = args.flag("quick");
    let write_pct: u32 = args.get("write-pct", 5);
    let dists: Vec<String> = if dist_arg == "both" {
        vec!["uniform".into(), "zipf".into()]
    } else {
        vec![dist_arg]
    };
    for dist in dists {
    let default_sizes: &[u64] = if quick {
        &[10, 1_000]
    } else {
        &[1, 10, 100, 1_000, 10_000, 100_000, 1_000_000]
    };
    let sizes = args.get_list::<u64>("sizes", default_sizes);
    let ops: u64 = args.get("ops", if quick { 2_000 } else { 5_000 });
    let client_threads: usize = args.get("client-threads", 2);

    println!("# Figure 8{} reproduction: KV store throughput (kOPs) vs table size, {write_pct}% writes",
             if dist == "uniform" { "a (uniform)" } else { "b (zipfian)" });
    println!("# paper: Trust16/Trust24 dedicated trustees; here TrustD2 = 2 dedicated of 4 workers");

    let header = vec!["keys", "TrustD2", "TrustS", "Dashmap-like", "Mutex", "RwLock"];
    let mut rows = Vec::new();
    for &keys in &sizes {
        let mut row = vec![keys.to_string()];
        for (backend, ded) in [
            (BackendKind::Trust { shards: 8 }, 2usize),
            (BackendKind::Trust { shards: 8 }, 0),
            (BackendKind::Swift, 0),
            (BackendKind::Mutex, 0),
            (BackendKind::RwLock, 0),
        ] {
            let tput = run_one(backend, ded, keys, &dist, write_pct, ops, client_threads);
            row.push(format!("{:.1}", tput / 1e3));
        }
        eprintln!("done keys={keys}");
        rows.push(row);
    }
    print_table(&format!("fig8 {dist}: kOPs vs table size"), &header, &rows);
    }
}
