//! E18: eviction under memory pressure — the unified item store's byte
//! budget + per-shard LRU, Trust vs the lock baselines, at varying
//! budget-to-working-set ratios.
//!
//! Each cell boots a RESP server with `budget_bytes` set to a fraction
//! of the prefilled working set and drives a write-heavy load: every
//! over-budget SET pays a victim scan + reclamation on the owning shard
//! (trustee-local for Trust, lock-scoped for the baselines). Reported
//! per cell: kOPs, evictions, and final store bytes — the ratio across
//! backends is the signal (absolute numbers are box-dependent).
//!
//! Usage: cargo bench --bench eviction_pressure -- \
//!            [--keys N] [--val-len L] [--ops N] [--write-pct P]
//!            [--ratios 100,50,25] [--quick] [--json]
//!
//! With `--json`, one machine-readable object is printed to stdout —
//! `scripts/bench_smoke.sh` captures it as `BENCH_eviction_pressure.json`
//! for cross-PR comparison.

use trustee::bench::print_table;
use trustee::kvstore::store::ITEM_OVERHEAD;
use trustee::kvstore::BackendKind;
use trustee::server::{run_resp_load, RespLoadConfig, RespServer, RespServerConfig};
use trustee::util::cli::Args;

fn main() {
    let args = Args::from_env();
    let quick = args.flag("quick");
    let json = args.flag("json");
    // Working set sized so the *smallest* ratio still leaves the
    // 512-shard lock baselines several entries of budget per shard —
    // otherwise a SET evicts its own key and the cell measures an empty
    // store instead of eviction cost (see the degeneracy guard below).
    let keys: u64 = args.get("keys", if quick { 8_000 } else { 16_000 });
    let val_len: usize = args.get("val-len", 64);
    let ops: u64 = args.get("ops", if quick { 1_500 } else { 5_000 });
    let write_pct: u32 = args.get("write-pct", 50);
    // Budget as a percentage of the prefilled working set; 100 barely
    // evicts (steady churn), 25 keeps the store under heavy pressure.
    let ratios = args.get_list::<u64>("ratios", if quick { &[100, 25] } else { &[100, 50, 25] });
    // `key:<n>` keys run ~8 bytes at these sizes.
    let entry_cost = 8 + val_len as u64 + ITEM_OVERHEAD;
    let working_set = keys * entry_cost;

    if !json {
        println!(
            "# E18: eviction under memory pressure ({keys} keys x {val_len}B, \
             working set ~{working_set}B, {write_pct}% writes); \
             cell = kOPs (evictions)"
        );
    }

    let configs = [
        ("TrustS", BackendKind::Trust { shards: 8 }),
        ("Mutex", BackendKind::Mutex),
        ("RwLock", BackendKind::RwLock),
    ];
    let header = vec!["budget_pct", "TrustS", "Mutex", "RwLock"];
    let mut rows = Vec::new();
    let mut json_rows: Vec<String> = Vec::new();
    for &ratio in &ratios {
        let budget = working_set * ratio / 100;
        // Degeneracy guard: the budget splits per shard, and the lock
        // baselines run 512 shards. If a shard's slice cannot hold a
        // couple of entries, every SET self-evicts and the cell is
        // meaningless — flag it rather than report it silently.
        if budget > 0 && budget / 512 < 2 * entry_cost {
            eprintln!(
                "WARNING: budget_pct={ratio} gives {}B/shard on the 512-shard \
                 baselines (< 2 entries of {entry_cost}B) — raise --keys/--val-len",
                budget / 512
            );
        }
        let mut row = vec![ratio.to_string()];
        let mut cells: Vec<String> = Vec::new();
        for (label, backend) in configs.clone() {
            let server = RespServer::start(RespServerConfig {
                workers: 4,
                dedicated: 0,
                backend,
                budget_bytes: budget,
                addr: "127.0.0.1:0".into(),
                ..Default::default()
            });
            server.prefill(keys, val_len);
            let stats = run_resp_load(&RespLoadConfig {
                addr: server.addr(),
                threads: 2,
                pipeline: 32,
                ops_per_thread: ops,
                keys,
                dist: "uniform".into(),
                write_pct,
                ttl_pct: 0,
                val_len,
                seed: 0xE18,
            });
            if !stats.ok() {
                eprintln!("client errors: {:?}", stats.errors);
            }
            let store = server.store_stats();
            let kops = stats.throughput() / 1e3;
            row.push(format!("{kops:.1} ({})", store.evictions));
            cells.push(format!(
                "\"{label}\":{{\"kops\":{kops:.2},\"evictions\":{},\
                 \"expired_keys\":{},\"store_bytes\":{},\"items\":{}}}",
                store.evictions, store.expired_keys, store.store_bytes, store.items
            ));
            server.stop();
        }
        eprintln!("done budget_pct={ratio}");
        json_rows.push(format!("{{\"budget_pct\":{ratio},{}}}", cells.join(",")));
        rows.push(row);
    }
    if json {
        println!(
            "{{\"bench\":\"eviction_pressure\",\"keys\":{keys},\"val_len\":{val_len},\
             \"write_pct\":{write_pct},\"working_set_bytes\":{working_set},\
             \"rows\":[{}]}}",
            json_rows.join(",")
        );
    } else {
        print_table("E18: kOPs (evictions) vs budget ratio", &header, &rows);
    }
}
