//! E18/E20: eviction under memory pressure — the unified item store's
//! byte budget + intrusive LRU, Trust vs the lock baselines, at varying
//! budget-to-working-set ratios, plus a deep-churn cell (E20) where the
//! budget is a small fraction of the key space and ~every SET is a
//! miss-insert that evicts.
//!
//! Each cell boots a RESP server with `budget_bytes` set to a fraction
//! of the prefilled working set and drives a write-heavy load: every
//! over-budget SET pays an O(1) tail unlink + reclamation on the owning
//! shard (trustee-local for Trust, lock-scoped for the baselines).
//! Reported per cell: kOPs, evictions, and final store bytes; the
//! deep-churn cell adds evictions/sec and the value-slab free-list hit
//! rate (pool-served buffer acquisitions / all acquisitions — 1.0 means
//! steady-state churn allocates nothing). The ratio across backends is
//! the signal (absolute numbers are box-dependent).
//!
//! Usage: cargo bench --bench eviction_pressure -- \
//!            [--keys N] [--val-len L] [--ops N] [--write-pct P]
//!            [--ratios 100,50,25] [--churn-pct P] [--quick] [--json]
//!
//! With `--json`, one machine-readable object is printed to stdout —
//! `scripts/bench_smoke.sh` captures it as `BENCH_eviction_pressure.json`
//! for cross-PR comparison.

use trustee::bench::print_table;
use trustee::kvstore::store::entry_cost;
use trustee::kvstore::BackendKind;
use trustee::server::{run_resp_load, RespLoadConfig, RespServer, RespServerConfig};
use trustee::util::cli::Args;

const CONFIGS: [(&str, BackendKind); 3] = [
    ("TrustS", BackendKind::Trust { shards: 8 }),
    ("Mutex", BackendKind::Mutex),
    ("RwLock", BackendKind::RwLock),
];

struct Cell {
    kops: f64,
    evictions_per_sec: f64,
    slab_hit_rate: f64,
    json: String,
}

/// One cell's load shape (the backend and its label vary per column).
struct CellCfg {
    budget: u64,
    /// Keys to prefill (0 = start empty — the deep-churn cell).
    prefill_keys: u64,
    keys: u64,
    val_len: usize,
    ops: u64,
    write_pct: u32,
}

/// Boot a server, run one load cell, and collect the stats that both
/// output modes need.
fn run_cell(backend: BackendKind, label: &str, cfg: &CellCfg) -> Cell {
    let server = RespServer::start(RespServerConfig {
        workers: 4,
        dedicated: 0,
        backend,
        budget_bytes: cfg.budget,
        addr: "127.0.0.1:0".into(),
        ..Default::default()
    });
    if cfg.prefill_keys > 0 {
        server.prefill(cfg.prefill_keys, cfg.val_len);
    }
    let stats = run_resp_load(&RespLoadConfig {
        addr: server.addr(),
        threads: 2,
        pipeline: 32,
        ops_per_thread: cfg.ops,
        keys: cfg.keys,
        dist: "uniform".into(),
        write_pct: cfg.write_pct,
        ttl_pct: 0,
        val_len: cfg.val_len,
        seed: 0xE18,
        retry_shed: false,
    });
    if !stats.ok() {
        eprintln!("client errors: {:?}", stats.errors);
    }
    let store = server.store_stats();
    server.stop();
    let kops = stats.throughput() / 1e3;
    let secs = stats.elapsed.as_secs_f64().max(1e-9);
    let evictions_per_sec = store.evictions as f64 / secs;
    let acquires = store.slab_hits + store.slab_misses;
    let slab_hit_rate = if acquires == 0 {
        0.0
    } else {
        store.slab_hits as f64 / acquires as f64
    };
    let json = format!(
        "\"{label}\":{{\"kops\":{kops:.2},\"evictions\":{},\
         \"evictions_per_sec\":{evictions_per_sec:.0},\
         \"expired_keys\":{},\"store_bytes\":{},\"items\":{},\
         \"slab_hits\":{},\"slab_misses\":{},\"slab_hit_rate\":{slab_hit_rate:.4},\
         \"slab_free_bytes\":{},\"slab_slack_bytes\":{}}}",
        store.evictions,
        store.expired_keys,
        store.store_bytes,
        store.items,
        store.slab_hits,
        store.slab_misses,
        store.slab_free_bytes,
        store.slab_slack_bytes,
    );
    Cell { kops, evictions_per_sec, slab_hit_rate, json }
}

fn main() {
    let args = Args::from_env();
    let quick = args.flag("quick");
    let json = args.flag("json");
    // Working set sized so the *smallest* ratio still leaves the
    // 512-shard lock baselines several entries of budget per shard —
    // otherwise a SET evicts its own key and the cell measures an empty
    // store instead of eviction cost (see the degeneracy guard below).
    let keys: u64 = args.get("keys", if quick { 8_000 } else { 16_000 });
    let val_len: usize = args.get("val-len", 64);
    let ops: u64 = args.get("ops", if quick { 1_500 } else { 5_000 });
    let write_pct: u32 = args.get("write-pct", 50);
    // Budget as a percentage of the prefilled working set; 100 barely
    // evicts (steady churn), 25 keeps the store under heavy pressure.
    let ratios = args.get_list::<u64>("ratios", if quick { &[100, 25] } else { &[100, 50, 25] });
    // Deep-churn (E20) budget as a percentage of the key space's bytes:
    // small enough that ~every SET misses, inserts, and evicts.
    let churn_pct: u64 = args.get("churn-pct", 10);
    // `key:<n>` keys run ~8 bytes at these sizes; value charges are
    // class-rounded, and entry_cost keeps that math in one place.
    let per_entry = entry_cost(8, val_len);
    let working_set = keys * per_entry;

    if !json {
        println!(
            "# E18: eviction under memory pressure ({keys} keys x {val_len}B, \
             working set ~{working_set}B, {write_pct}% writes); \
             cell = kOPs (evictions)"
        );
    }

    let header = vec!["budget_pct", "TrustS", "Mutex", "RwLock"];
    let mut rows = Vec::new();
    let mut json_rows: Vec<String> = Vec::new();
    for &ratio in &ratios {
        let budget = working_set * ratio / 100;
        // Degeneracy guard: the budget splits per shard, and the lock
        // baselines run 512 shards. If a shard's slice cannot hold a
        // couple of entries, every SET self-evicts and the cell is
        // meaningless — flag it rather than report it silently.
        if budget > 0 && budget / 512 < 2 * per_entry {
            eprintln!(
                "WARNING: budget_pct={ratio} gives {}B/shard on the 512-shard \
                 baselines (< 2 entries of {per_entry}B) — raise --keys/--val-len",
                budget / 512
            );
        }
        let cfg = CellCfg { budget, prefill_keys: keys, keys, val_len, ops, write_pct };
        let mut row = vec![ratio.to_string()];
        let mut cells: Vec<String> = Vec::new();
        for (label, backend) in CONFIGS {
            let cell = run_cell(backend, label, &cfg);
            row.push(format!("{:.1} ({:.0}/s)", cell.kops, cell.evictions_per_sec));
            cells.push(cell.json);
        }
        eprintln!("done budget_pct={ratio}");
        json_rows.push(format!("{{\"budget_pct\":{ratio},{}}}", cells.join(",")));
        rows.push(row);
    }

    // E20 deep churn: budget ≪ working set, 100% writes over the whole
    // key space, no prefill — nearly every SET is a miss-insert that
    // evicts the LRU tail. This is the cell that turns the old
    // O(capacity) victim scan into wall-clock (and now exercises the
    // O(1) unlink + slab recycling instead).
    let churn_budget = (working_set * churn_pct / 100).max(512 * 2 * per_entry);
    let churn_cfg =
        CellCfg { budget: churn_budget, prefill_keys: 0, keys, val_len, ops, write_pct: 100 };
    let mut churn_row = vec![format!("churn:{churn_pct}")];
    let mut churn_cells: Vec<String> = Vec::new();
    for (label, backend) in CONFIGS {
        let cell = run_cell(backend, label, &churn_cfg);
        churn_row.push(format!(
            "{:.1} ({:.0}/s, hit {:.2})",
            cell.kops, cell.evictions_per_sec, cell.slab_hit_rate
        ));
        churn_cells.push(cell.json);
    }
    eprintln!("done deep_churn churn_pct={churn_pct}");

    if json {
        println!(
            "{{\"bench\":\"eviction_pressure\",\"keys\":{keys},\"val_len\":{val_len},\
             \"write_pct\":{write_pct},\"working_set_bytes\":{working_set},\
             \"rows\":[{}],\
             \"deep_churn\":{{\"churn_pct\":{churn_pct},\"budget_bytes\":{churn_budget},{}}}}}",
            json_rows.join(","),
            churn_cells.join(",")
        );
    } else {
        print_table("E18: kOPs (evictions/s) vs budget ratio", &header, &rows);
        print_table(
            "E20: deep churn — kOPs (evictions/s, slab hit rate)",
            &header,
            &[churn_row],
        );
    }
}
