//! E14 — the L1/L2 extension: trustee-side batched apply through the
//! AOT-compiled XLA engine (JAX + Pallas, PJRT CPU) vs. the scalar
//! trustee loop applying the same operations one closure at a time.
//!
//! Run `make artifacts` first. Usage:
//!     cargo bench --bench xla_batch_apply -- [--batches N]

use trustee::bench::print_table;
use trustee::runtime::xla_exec::BatchEngine;
use trustee::util::cli::Args;
use trustee::util::Rng;
use std::time::Instant;

fn main() {
    let args = Args::from_env();
    let batches: u64 = args.get("batches", 200);

    let artifact = std::path::Path::new(env!("CARGO_MANIFEST_DIR"))
        .join("artifacts/batch_engine.hlo.txt");
    if !artifact.exists() {
        eprintln!("SKIP: {artifact:?} missing — run `make artifacts` first");
        return;
    }

    const N: usize = 65536;
    const B: usize = 256;
    let mut eng = BatchEngine::new(&artifact, N, B).expect("engine");
    let mut rng = Rng::new(0xBA7C);

    // Pre-generate the op stream.
    let mut keys = Vec::with_capacity((batches as usize) * B);
    let mut deltas = Vec::with_capacity((batches as usize) * B);
    for _ in 0..batches as usize * B {
        keys.push(rng.below(N as u64) as i32);
        deltas.push((rng.below(5) + 1) as i32);
    }

    // Scalar trustee loop (per-op closure application over a Vec table).
    let mut table = vec![0i32; N];
    let t0 = Instant::now();
    let mut checksum = 0i64;
    for i in 0..keys.len() {
        let k = keys[i] as usize;
        let old = table[k];
        checksum = checksum.wrapping_add(old as i64);
        table[k] = old + deltas[i];
    }
    let scalar_secs = t0.elapsed().as_secs_f64();

    // Warm up the executable, then run the batch engine.
    eng.apply_batch(&keys[..B], &deltas[..B]).unwrap();
    let mut eng = BatchEngine::new(&artifact, N, B).expect("engine reset");
    let t0 = Instant::now();
    let mut xla_checksum = 0i64;
    for b in 0..batches as usize {
        let lo = b * B;
        let old = eng.apply_batch(&keys[lo..lo + B], &deltas[lo..lo + B]).unwrap();
        for o in old {
            xla_checksum = xla_checksum.wrapping_add(o as i64);
        }
    }
    let xla_secs = t0.elapsed().as_secs_f64();

    assert_eq!(checksum, xla_checksum, "engines disagree");
    assert_eq!(eng.table().unwrap(), table, "final tables disagree");

    let total_ops = (batches as usize * B) as f64;
    print_table(
        "E14: batched apply — scalar trustee loop vs AOT XLA engine (numerics verified equal)",
        &["engine", "ops/s", "ns/op"],
        &[
            vec![
                "scalar loop".into(),
                format!("{:.0}", total_ops / scalar_secs),
                format!("{:.1}", scalar_secs / total_ops * 1e9),
            ],
            vec![
                format!("xla batch (B={B})"),
                format!("{:.0}", total_ops / xla_secs),
                format!("{:.1}", xla_secs / total_ops * 1e9),
            ],
        ],
    );
    println!("# note: interpret=True Pallas on CPU-PJRT measures *dispatch* cost, not TPU");
    println!("# perf; see DESIGN.md \"Perf (L1)\" for the VMEM-footprint analysis.");
}
