//! Figures 10 & 11: mini-memcached throughput vs. table size, for 1%, 5%
//! and 10% writes — stock (lock-based) vs. Trust\<T\> (delegated shards).
//!
//! `--dist uniform` regenerates Fig. 10; `--dist zipf` regenerates Fig. 11.
//!
//! Usage: cargo bench --bench fig10_11_memcached -- \
//!            [--dist uniform|zipf] [--sizes 100,10000,...] [--pcts 1,5,10]
//!            [--quick]

use trustee::bench::print_table;
use trustee::kvstore::BackendKind;
use trustee::memcache::{run_memtier, McdServer, McdServerConfig, MemtierConfig};
use trustee::util::cli::Args;

fn run_one(backend: BackendKind, keys: u64, dist: &str, write_pct: u32, ops: u64) -> f64 {
    let server = McdServer::start(McdServerConfig {
        workers: 4,
        dedicated: 0,
        backend,
        addr: "127.0.0.1:0".into(),
        ..Default::default()
    });
    server.prefill(keys, 16);
    let stats = run_memtier(&MemtierConfig {
        addr: server.addr(),
        threads: 2,
        pipeline: 48, // the paper's memtier pipelining
        ops_per_thread: ops,
        keys,
        dist: dist.into(),
        write_pct,
        ttl_pct: 0,
        val_len: 16,
        seed: 0x3E3C,
        retry_shed: false,
    });
    let tput = stats.throughput();
    server.stop();
    tput
}

fn main() {
    let args = Args::from_env();
    let dist_arg = args.get_str("dist", "both");
    let quick = args.flag("quick");
    let dists: Vec<String> = if dist_arg == "both" {
        vec!["uniform".into(), "zipf".into()]
    } else {
        vec![dist_arg]
    };
    for dist in dists {
    let default_sizes: &[u64] = if quick {
        &[100, 10_000]
    } else {
        &[100, 1_000, 10_000, 100_000]
    };
    let sizes = args.get_list::<u64>("sizes", default_sizes);
    let pcts = args.get_list::<u32>("pcts", if quick { &[5] } else { &[1, 5, 10] });
    let ops: u64 = args.get("ops", if quick { 2_000 } else { 5_000 });

    println!("# Figure {} reproduction: mini-memcached throughput (kOPs) vs table size",
             if dist == "uniform" { "10 (uniform)" } else { "11 (zipfian)" });
    println!("# S = lock baseline (unified store, 512 Mutex shards — less contended than");
    println!("#     true stock memcached's global LRU, so speedups read conservative),");
    println!("# T = Trust<T> delegated shards; paper pipeline=48");

    let mut header = vec!["keys".to_string()];
    for &p in &pcts {
        header.push(format!("S-{p}%w"));
        header.push(format!("T-{p}%w"));
        header.push(format!("speedup-{p}%w"));
    }
    let mut rows = Vec::new();
    for &keys in &sizes {
        let mut row = vec![keys.to_string()];
        for &pct in &pcts {
            let s = run_one(BackendKind::Mutex, keys, &dist, pct, ops);
            let t = run_one(BackendKind::Trust { shards: 8 }, keys, &dist, pct, ops);
            row.push(format!("{:.1}", s / 1e3));
            row.push(format!("{:.1}", t / 1e3));
            row.push(format!("{:.2}x", t / s));
        }
        eprintln!("done keys={keys}");
        rows.push(row);
    }
    let header_refs: Vec<&str> = header.iter().map(|s| s.as_str()).collect();
    print_table(&format!("fig10/11 {dist}"), &header_refs, &rows);
    }
}
