//! E15 — idle-connection overhead: per-op latency on one active KV
//! connection while N idle connections sit open.
//!
//! Under `NetPolicy::BusyPoll` every idle connection fiber is re-run (and
//! re-`read()`s its socket) on every scheduler tick, so idle connections
//! steal serve-phase capacity from the trustees and per-op latency
//! degrades with connection count. Under `NetPolicy::Epoll` idle fibers
//! are parked on fd readiness in the per-worker reactor — O(ready fds)
//! per tick — so the active connection's latency should stay within ~2x
//! of the 0-idle baseline regardless of how many connections sit idle.
//! `NetPolicy::IoUring` parks the same way but *stages* its polls into
//! the worker's submission ring — one `io_uring_enter` per scheduler
//! loop — so the sweep also records the submission-batching counters.
//!
//! Usage: cargo bench --bench net_idle_conns -- [--ops N] [--idle N]
//!
//! Connection-scale sweep (E21): `--sweep` walks a connection ladder
//! (default 1000,10000,100000 — clamped to the process fd budget with a
//! visible message) with a mixed idle/active population (`--active-pct`,
//! default 1%) under all three policies, and `--json` emits one
//! machine-readable object (captured by `scripts/bench_smoke.sh` as
//! `BENCH_net_idle_conns.json`):
//!
//!   cargo bench --bench net_idle_conns -- --sweep --json \
//!       [--conns 1000,10000,100000] [--ops N] [--active-pct P] \
//!       [--policies busy,epoll,uring,uring-data]
//!
//! In the sweep, `uring` pins the *readiness* plane (poll wake + `read`)
//! and `uring-data` pins the *data* plane (provided-buffer multishot
//! RECV + ring SEND; skipped with a note on kernels without
//! `IORING_REGISTER_PBUF_RING`), so a ladder run distinguishes the two
//! planes' idle-scale behaviour in one JSON object.

use std::io::{Read, Write};
use std::net::TcpStream;
use trustee::bench::print_table;
use trustee::kvstore::{proto, BackendKind, KvServer, KvServerConfig, NetPolicy};
use trustee::util::cli::Args;
use trustee::util::stats::fmt_ns;

/// Synchronous GET round trip on a blocking socket.
fn sync_get(c: &mut TcpStream, id: u64, key: &[u8]) {
    let mut buf = Vec::new();
    proto::write_request(&mut buf, id, proto::OP_GET, key, &[]);
    c.write_all(&buf).unwrap();
    let mut cursor = proto::FrameCursor::new();
    let mut rbuf = Vec::new();
    let mut chunk = [0u8; 4096];
    loop {
        if let Some(r) = cursor.next_response(&rbuf).unwrap() {
            assert_eq!(r.id, id);
            return;
        }
        let n = c.read(&mut chunk).unwrap();
        assert!(n > 0, "server closed");
        rbuf.extend_from_slice(&chunk[..n]);
    }
}

/// Mean per-op latency (ns) of one active connection with `idle`
/// additional connections sitting open and silent.
fn per_op_ns(net: NetPolicy, idle: usize, ops: u64) -> f64 {
    let server = KvServer::start(KvServerConfig {
        workers: 2,
        backend: BackendKind::Trust { shards: 2 },
        net,
        ..Default::default()
    });
    server.prefill(64, 16);
    let _idle_conns: Vec<TcpStream> = (0..idle)
        .map(|_| TcpStream::connect(server.addr()).unwrap())
        .collect();
    let mut active = TcpStream::connect(server.addr()).unwrap();
    active.set_nodelay(true).ok();
    // Let the idle fibers spawn and reach their steady state (parked under
    // Epoll, yield-looping under BusyPoll).
    std::thread::sleep(std::time::Duration::from_millis(100));
    for i in 0..200u64 {
        sync_get(&mut active, i, &trustee::kvstore::key_bytes(i % 64));
    }
    let t0 = std::time::Instant::now();
    for i in 0..ops {
        sync_get(&mut active, 1000 + i, &trustee::kvstore::key_bytes(i % 64));
    }
    let elapsed = t0.elapsed().as_secs_f64();
    drop(active);
    server.stop();
    elapsed / ops as f64 * 1e9
}

/// Loopback connections this process can hold open: each one consumes
/// two fds here (client end + server end), plus headroom for the rest of
/// the process. A sweep rung above this is clamped with a visible note —
/// the full 100k rung needs a host with `ulimit -n` ≳ 210k.
fn conn_budget() -> usize {
    let soft = std::fs::read_to_string("/proc/self/limits")
        .ok()
        .and_then(|s| {
            s.lines()
                .find(|l| l.starts_with("Max open files"))
                .and_then(|l| l.split_whitespace().nth(3)?.parse::<usize>().ok())
        })
        .unwrap_or(1024);
    soft.saturating_sub(256) / 2
}

/// One sweep cell: `conns` open connections of which `active` issue
/// round-robin sync GETs; returns (connections actually opened, mean
/// per-op ns, server uring totals).
fn sweep_cell(
    net: NetPolicy,
    conns: usize,
    active: usize,
    ops: u64,
) -> (usize, f64, trustee::runtime::uring::UringStats) {
    let server = KvServer::start(KvServerConfig {
        workers: 2,
        backend: BackendKind::Trust { shards: 2 },
        net,
        ..Default::default()
    });
    server.prefill(64, 16);
    let mut pool: Vec<TcpStream> = Vec::with_capacity(conns);
    for i in 0..conns {
        match TcpStream::connect(server.addr()) {
            Ok(s) => pool.push(s),
            Err(e) => {
                eprintln!("sweep: stopped opening at {i}/{conns} connections ({e})");
                break;
            }
        }
        // Brief pauses keep the accept backlog from overflowing while the
        // single-core server spawns fibers for a large wave.
        if i % 500 == 499 {
            std::thread::sleep(std::time::Duration::from_millis(10));
        }
    }
    let opened = pool.len();
    let active = active.min(opened).max(1);
    for s in pool.iter_mut().take(active) {
        s.set_nodelay(true).ok();
    }
    // Let the idle population reach steady state (parked under
    // epoll/uring, yield-looping under busy-poll).
    std::thread::sleep(std::time::Duration::from_millis(200));
    let warmup = (active as u64 * 4).min(ops);
    for i in 0..warmup {
        let c = &mut pool[(i as usize) % active];
        sync_get(c, i, &trustee::kvstore::key_bytes(i % 64));
    }
    let t0 = std::time::Instant::now();
    for i in 0..ops {
        let c = &mut pool[(i as usize) % active];
        sync_get(c, (1u64 << 32) | i, &trustee::kvstore::key_bytes(i % 64));
    }
    let per_op_ns = t0.elapsed().as_secs_f64() / ops as f64 * 1e9;
    let uring = server.uring_stats();
    drop(pool);
    server.stop();
    (opened, per_op_ns, uring)
}

fn run_sweep(args: &Args) {
    let json = args.flag("json");
    let ops: u64 = args.get("ops", 2_000);
    let active_pct: usize = args.get("active-pct", 1);
    let ladder = args.get_str("conns", "1000,10000,100000");
    let policy_spec = args.get_str("policies", "busy,epoll,uring,uring-data");
    // (policy, pin data plane). In sweep mode plain `uring` pins the
    // readiness plane so the `uring-data` cell is a true A/B, not
    // whatever the kernel happens to auto-engage.
    let policies: Vec<(NetPolicy, bool)> = policy_spec
        .split(',')
        .map(|s| match s.trim() {
            "uring-data" => (NetPolicy::IoUring, true),
            other => (
                NetPolicy::from_spec(other).unwrap_or_else(|e| panic!("--policies: {e}")),
                false,
            ),
        })
        .collect();
    let pbuf_ok = trustee::runtime::uring::probe_pbuf().is_ok();
    let dataplane_orig = trustee::runtime::uring::dataplane_enabled();
    let budget = conn_budget();
    let mut rows = Vec::new();
    let mut cells: Vec<String> = Vec::new();
    for &(net, want_data) in &policies {
        if want_data && !pbuf_ok {
            eprintln!("sweep: skipping uring-data cells (PBUF_RING unavailable on this kernel)");
            continue;
        }
        let label: String = if net == NetPolicy::IoUring {
            if want_data { "uring+pbuf".into() } else { "uring".into() }
        } else {
            net.label().into()
        };
        for rung in ladder.split(',') {
            let requested: usize = rung.trim().parse().expect("bad --conns entry");
            let conns = requested.min(budget);
            if conns < requested {
                // Each loopback connection costs two fds in this process
                // (client end + server end), plus fixed headroom.
                eprintln!(
                    "sweep: clamped {requested} -> {conns} connections \
                     (process fd budget {budget}; this rung needs ulimit -n >= {})",
                    requested * 2 + 256
                );
            }
            let active = (conns * active_pct / 100).max(1);
            if net == NetPolicy::IoUring {
                trustee::runtime::uring::set_dataplane_enabled(want_data);
            }
            let (opened, per_op, uring) = sweep_cell(net, conns, active, ops);
            if net == NetPolicy::IoUring {
                trustee::runtime::uring::set_dataplane_enabled(dataplane_orig);
            }
            let sqes_per_enter = if uring.enters > 0 {
                uring.sqes_submitted as f64 / uring.enters as f64
            } else {
                0.0
            };
            eprintln!("done {label} conns={opened} active={active}: {} per op", fmt_ns(per_op));
            rows.push(vec![
                label.clone(),
                format!("{opened} (req {requested})"),
                active.to_string(),
                fmt_ns(per_op),
                if want_data {
                    format!(
                        "{sqes_per_enter:.1} sqes/enter, {} recv-cqe, {} recycled",
                        uring.recv_cqes, uring.pbuf_recycled
                    )
                } else if uring.enters > 0 {
                    format!("{sqes_per_enter:.1} sqes/enter")
                } else {
                    String::new()
                },
            ]);
            cells.push(format!(
                "{{\"policy\":\"{label}\",\"plane\":\"{}\",\
                 \"conns_requested\":{requested},\"conns\":{opened},\
                 \"active\":{active},\"ops\":{ops},\"per_op_ns\":{per_op:.1},\
                 \"uring_enters\":{},\"uring_sqes\":{},\"uring_cqes\":{},\
                 \"uring_sq_full_flushes\":{},\"uring_enter_waits\":{},\
                 \"uring_max_sqes_per_enter\":{},\"sqes_per_enter\":{sqes_per_enter:.2},\
                 \"recv_cqes\":{},\"pbuf_recycled\":{},\"enobufs\":{},\"send_sqes\":{},\
                 \"short_send_continuations\":{}}}",
                if net != NetPolicy::IoUring {
                    ""
                } else if want_data {
                    "data"
                } else {
                    "readiness"
                },
                uring.enters,
                uring.sqes_submitted,
                uring.cqes_harvested,
                uring.sq_full_flushes,
                uring.enter_waits,
                uring.max_sqes_per_enter,
                uring.recv_cqes,
                uring.pbuf_recycled,
                uring.enobufs,
                uring.send_sqes,
                uring.short_send_continuations,
            ));
        }
    }
    if json {
        println!(
            "{{\"bench\":\"net_idle_conns\",\"mode\":\"sweep\",\"active_pct\":{active_pct},\
             \"fd_budget\":{budget},\"pbuf_capable\":{pbuf_ok},\"cells\":[{}]}}",
            cells.join(",")
        );
    } else {
        print_table(
            "E21: connection-scale sweep (mixed idle/active; per-policy latency curve)",
            &["policy", "conns", "active", "per-op latency", "uring batching"],
            &rows,
        );
    }
}

fn main() {
    let args = Args::from_env();
    let ops: u64 = args.get("ops", 3_000);
    let idle: usize = args.get("idle", 64);
    if args.flag("sweep") {
        run_sweep(&args);
        return;
    }

    let mut rows = Vec::new();
    let mut ratios = Vec::new();
    for net in [NetPolicy::BusyPoll, NetPolicy::Epoll, NetPolicy::IoUring] {
        let base = per_op_ns(net, 0, ops);
        let loaded = per_op_ns(net, idle, ops);
        let ratio = loaded / base;
        ratios.push((net, ratio));
        rows.push(vec![
            net.label().into(),
            "0".into(),
            fmt_ns(base),
            String::new(),
        ]);
        rows.push(vec![
            net.label().into(),
            idle.to_string(),
            fmt_ns(loaded),
            format!("{ratio:.2}x vs 0-idle"),
        ]);
        eprintln!("done {}", net.label());
    }
    print_table(
        &format!(
            "E15: per-op latency, 1 active + N idle connections (acceptance: \
             epoll within 2x of its 0-idle baseline at {idle} idle; busy-poll degrades)"
        ),
        &["policy", "idle conns", "per-op latency", "degradation"],
        &rows,
    );
    for (net, ratio) in ratios {
        println!("{}: {idle}-idle/0-idle latency ratio = {ratio:.2}x", net.label());
    }
}
