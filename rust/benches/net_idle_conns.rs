//! E15 — idle-connection overhead: per-op latency on one active KV
//! connection while N idle connections sit open.
//!
//! Under `NetPolicy::BusyPoll` every idle connection fiber is re-run (and
//! re-`read()`s its socket) on every scheduler tick, so idle connections
//! steal serve-phase capacity from the trustees and per-op latency
//! degrades with connection count. Under `NetPolicy::Epoll` idle fibers
//! are parked on fd readiness in the per-worker reactor — O(ready fds)
//! per tick — so the active connection's latency should stay within ~2x
//! of the 0-idle baseline regardless of how many connections sit idle.
//!
//! Usage: cargo bench --bench net_idle_conns -- [--ops N] [--idle N]

use std::io::{Read, Write};
use std::net::TcpStream;
use trustee::bench::print_table;
use trustee::kvstore::{proto, BackendKind, KvServer, KvServerConfig, NetPolicy};
use trustee::util::cli::Args;
use trustee::util::stats::fmt_ns;

/// Synchronous GET round trip on a blocking socket.
fn sync_get(c: &mut TcpStream, id: u64, key: &[u8]) {
    let mut buf = Vec::new();
    proto::write_request(&mut buf, id, proto::OP_GET, key, &[]);
    c.write_all(&buf).unwrap();
    let mut cursor = proto::FrameCursor::new();
    let mut rbuf = Vec::new();
    let mut chunk = [0u8; 4096];
    loop {
        if let Some(r) = cursor.next_response(&rbuf).unwrap() {
            assert_eq!(r.id, id);
            return;
        }
        let n = c.read(&mut chunk).unwrap();
        assert!(n > 0, "server closed");
        rbuf.extend_from_slice(&chunk[..n]);
    }
}

/// Mean per-op latency (ns) of one active connection with `idle`
/// additional connections sitting open and silent.
fn per_op_ns(net: NetPolicy, idle: usize, ops: u64) -> f64 {
    let server = KvServer::start(KvServerConfig {
        workers: 2,
        backend: BackendKind::Trust { shards: 2 },
        net,
        ..Default::default()
    });
    server.prefill(64, 16);
    let _idle_conns: Vec<TcpStream> = (0..idle)
        .map(|_| TcpStream::connect(server.addr()).unwrap())
        .collect();
    let mut active = TcpStream::connect(server.addr()).unwrap();
    active.set_nodelay(true).ok();
    // Let the idle fibers spawn and reach their steady state (parked under
    // Epoll, yield-looping under BusyPoll).
    std::thread::sleep(std::time::Duration::from_millis(100));
    for i in 0..200u64 {
        sync_get(&mut active, i, &trustee::kvstore::key_bytes(i % 64));
    }
    let t0 = std::time::Instant::now();
    for i in 0..ops {
        sync_get(&mut active, 1000 + i, &trustee::kvstore::key_bytes(i % 64));
    }
    let elapsed = t0.elapsed().as_secs_f64();
    drop(active);
    server.stop();
    elapsed / ops as f64 * 1e9
}

fn main() {
    let args = Args::from_env();
    let ops: u64 = args.get("ops", 3_000);
    let idle: usize = args.get("idle", 64);

    let mut rows = Vec::new();
    let mut ratios = Vec::new();
    for net in [NetPolicy::BusyPoll, NetPolicy::Epoll] {
        let base = per_op_ns(net, 0, ops);
        let loaded = per_op_ns(net, idle, ops);
        let ratio = loaded / base;
        ratios.push((net, ratio));
        rows.push(vec![
            net.label().into(),
            "0".into(),
            fmt_ns(base),
            String::new(),
        ]);
        rows.push(vec![
            net.label().into(),
            idle.to_string(),
            fmt_ns(loaded),
            format!("{ratio:.2}x vs 0-idle"),
        ]);
        eprintln!("done {}", net.label());
    }
    print_table(
        &format!(
            "E15: per-op latency, 1 active + N idle connections (acceptance: \
             epoll within 2x of its 0-idle baseline at {idle} idle; busy-poll degrades)"
        ),
        &["policy", "idle conns", "per-op latency", "degradation"],
        &rows,
    );
    for (net, ratio) in ratios {
        println!("{}: {idle}-idle/0-idle latency ratio = {ratio:.2}x", net.label());
    }
}
