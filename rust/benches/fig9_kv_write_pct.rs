//! Figure 9a/9b: key-value store throughput vs. write percentage.
//!
//! Paper setup: 1,000 keys uniform / 10,000,000 keys zipfian — "table
//! sizes where lock-based approaches hold an advantage in Fig. 8".
//!
//! Usage: cargo bench --bench fig9_kv_write_pct -- \
//!            [--dist uniform|zipf] [--keys N] [--pcts 0,5,25,...] [--quick]

use trustee::bench::print_table;
use trustee::kvstore::{run_load, BackendKind, KvServer, KvServerConfig, LoadConfig};
use trustee::util::cli::Args;

fn main() {
    let args = Args::from_env();
    let dist_arg = args.get_str("dist", "both");
    let quick = args.flag("quick");
    let dists: Vec<String> = if dist_arg == "both" {
        vec!["uniform".into(), "zipf".into()]
    } else {
        vec![dist_arg]
    };
    for dist in dists {
    let keys: u64 = args.get("keys", if dist == "uniform" { 1_000 } else { 100_000 });
    let default_pcts: &[u32] = if quick { &[5, 50] } else { &[0, 5, 25, 50, 75, 100] };
    let pcts = args.get_list::<u32>("pcts", default_pcts);
    let ops: u64 = args.get("ops", if quick { 2_000 } else { 5_000 });
    let client_threads: usize = args.get("client-threads", 2);

    println!("# Figure 9{} reproduction: KV store throughput (kOPs) vs write %, {keys} keys",
             if dist == "uniform" { "a (uniform)" } else { "b (zipfian)" });

    let header = vec!["write_pct", "TrustD2", "TrustS", "Dashmap-like", "Mutex", "RwLock"];
    let mut rows = Vec::new();
    for &pct in &pcts {
        let mut row = vec![pct.to_string()];
        for (backend, ded) in [
            (BackendKind::Trust { shards: 8 }, 2usize),
            (BackendKind::Trust { shards: 8 }, 0),
            (BackendKind::Swift, 0),
            (BackendKind::Mutex, 0),
            (BackendKind::RwLock, 0),
        ] {
            let server = KvServer::start(KvServerConfig {
                workers: 4,
                dedicated: ded,
                backend,
                addr: "127.0.0.1:0".into(),
                ..Default::default()
            });
            server.prefill(keys, 16);
            let stats = run_load(&LoadConfig {
                addr: server.addr(),
                threads: client_threads,
                pipeline: 32,
                ops_per_thread: ops,
                keys,
                dist: dist.clone(),
                write_pct: pct,
                val_len: 16,
                seed: 0xF19,
            });
            row.push(format!("{:.1}", stats.throughput() / 1e3));
            server.stop();
        }
        eprintln!("done write_pct={pct}");
        rows.push(row);
    }
    print_table(&format!("fig9 {dist}: kOPs vs write %"), &header, &rows);
    }
}
