//! Figure 9a/9b: key-value store throughput vs. write percentage.
//!
//! Paper setup: 1,000 keys uniform / 10,000,000 keys zipfian — "table
//! sizes where lock-based approaches hold an advantage in Fig. 8".
//!
//! Usage: cargo bench --bench fig9_kv_write_pct -- \
//!            [--dist uniform|zipf] [--keys N] [--pcts 0,5,25,...]
//!            [--quick] [--json]
//!
//! With `--json`, one machine-readable object (all dists, all rows) is
//! printed to stdout — `scripts/bench_smoke.sh` captures it as
//! `BENCH_fig9_kv_write_pct.json` for cross-PR comparison.

use trustee::bench::print_table;
use trustee::kvstore::{run_load, BackendKind, KvServer, KvServerConfig, LoadConfig};
use trustee::util::cli::Args;

fn main() {
    let args = Args::from_env();
    let dist_arg = args.get_str("dist", "both");
    let quick = args.flag("quick");
    let json = args.flag("json");
    let dists: Vec<String> = if dist_arg == "both" {
        vec!["uniform".into(), "zipf".into()]
    } else {
        vec![dist_arg]
    };
    let mut json_rows: Vec<String> = Vec::new();
    for dist in dists {
        let keys: u64 = args.get("keys", if dist == "uniform" { 1_000 } else { 100_000 });
        let default_pcts: &[u32] = if quick { &[5, 50] } else { &[0, 5, 25, 50, 75, 100] };
        let pcts = args.get_list::<u32>("pcts", default_pcts);
        let ops: u64 = args.get("ops", if quick { 2_000 } else { 5_000 });
        let client_threads: usize = args.get("client-threads", 2);

        if !json {
            println!(
                "# Figure 9{} reproduction: KV store throughput (kOPs) vs write %, {keys} keys",
                if dist == "uniform" { "a (uniform)" } else { "b (zipfian)" }
            );
        }

        let configs = [
            ("TrustD2", BackendKind::Trust { shards: 8 }, 2usize),
            ("TrustS", BackendKind::Trust { shards: 8 }, 0),
            ("Dashmap-like", BackendKind::Swift, 0),
            ("Mutex", BackendKind::Mutex, 0),
            ("RwLock", BackendKind::RwLock, 0),
        ];
        let header = vec!["write_pct", "TrustD2", "TrustS", "Dashmap-like", "Mutex", "RwLock"];
        let mut rows = Vec::new();
        for &pct in &pcts {
            let mut row = vec![pct.to_string()];
            let mut cells: Vec<String> = Vec::new();
            for (label, backend, ded) in configs.clone() {
                let server = KvServer::start(KvServerConfig {
                    workers: 4,
                    dedicated: ded,
                    backend,
                    addr: "127.0.0.1:0".into(),
                    ..Default::default()
                });
                server.prefill(keys, 16);
                let stats = run_load(&LoadConfig {
                    addr: server.addr(),
                    threads: client_threads,
                    pipeline: 32,
                    ops_per_thread: ops,
                    keys,
                    dist: dist.clone(),
                    write_pct: pct,
                    val_len: 16,
                    seed: 0xF19,
                    retry_shed: false,
                });
                let kops = stats.throughput() / 1e3;
                row.push(format!("{kops:.1}"));
                cells.push(format!("\"{label}\":{kops:.2}"));
                server.stop();
            }
            eprintln!("done dist={dist} write_pct={pct}");
            json_rows.push(format!(
                "{{\"dist\":\"{dist}\",\"keys\":{keys},\"write_pct\":{pct},{}}}",
                cells.join(",")
            ));
            rows.push(row);
        }
        if !json {
            print_table(&format!("fig9 {dist}: kOPs vs write %"), &header, &rows);
        }
    }
    if json {
        println!(
            "{{\"bench\":\"fig9_kv_write_pct\",\"unit\":\"kOPs\",\"rows\":[{}]}}",
            json_rows.join(",")
        );
    }
}
