//! Figure 7a/7b: mean latency vs. offered load (plus the §6.2 tail-latency
//! observations: lock p99.9 ≈ 10× mean; delegation p99.9 ≈ 2.5× mean).
//!
//! Series: spinlock / Mutex / MCS / Trust shared / Trust dedicated.
//!
//! Usage: cargo bench --bench fig7_fetch_add_latency -- \
//!            [--dist uniform|zipf] [--threads N] [--loads 10000,...] [--quick]

use trustee::bench::latency::{run_latency_lock, run_latency_trust, LatencyConfig};
use trustee::bench::print_table;
use trustee::util::cli::Args;

fn main() {
    let args = Args::from_env();
    let dist_arg = args.get_str("dist", "both");
    let quick = args.flag("quick");
    let threads: usize = args.get("threads", 4);
    let dists: Vec<String> = if dist_arg == "both" {
        vec!["uniform".into(), "zipf".into()]
    } else {
        vec![dist_arg]
    };
    for dist in dists {
    // Paper: 64 objects uniform / 1,000,000 objects zipfian.
    let objects: usize = args.get(
        "objects",
        if dist == "uniform" { 64 } else { 100_000 },
    );
    let default_loads: &[f64] = if quick {
        &[20_000.0, 200_000.0]
    } else {
        &[10_000.0, 30_000.0, 100_000.0, 300_000.0, 1_000_000.0, 3_000_000.0]
    };
    let loads = args.get_list::<f64>("loads", default_loads);
    let secs: f64 = args.get("secs", 0.4);
    let dedicated: usize = args.get("dedicated", 1);

    println!("# Figure 7{} reproduction: mean latency (us) vs offered load",
             if dist == "uniform" { "a (uniform, 64 objects)" } else { "b (zipfian)" });
    println!("# threads={threads} objects={objects} (paper: 8 dedicated / 64 shared trustees)");

    let header = vec![
        "offered_ops", "spin_mean", "spin_p999", "mutex_mean", "mutex_p999",
        "mcs_mean", "mcs_p999", "trust_shared_mean", "trust_shared_p999",
        "trust_ded_mean", "trust_ded_p999", "achieved_trust",
    ];
    let mut rows = Vec::new();
    for &load in &loads {
        let ops_per_thread =
            ((load * secs / threads as f64) as u64).clamp(200, 50_000);
        let cfg = LatencyConfig {
            threads,
            objects,
            offered_ops_per_sec: load,
            ops_per_thread,
            dist: dist.clone(),
            seed: 0x717,
            dedicated: 0,
        };
        let mut row = vec![format!("{load:.0}")];
        for name in ["spin", "mutex", "mcs"] {
            let r = run_latency_lock(name, &cfg);
            row.push(format!("{:.1}", r.mean_us()));
            row.push(format!("{:.1}", r.p999_us()));
        }
        let r = run_latency_trust(&cfg);
        row.push(format!("{:.1}", r.mean_us()));
        row.push(format!("{:.1}", r.p999_us()));
        let rd = run_latency_trust(&LatencyConfig { dedicated, ..cfg.clone() });
        row.push(format!("{:.1}", rd.mean_us()));
        row.push(format!("{:.1}", rd.p999_us()));
        row.push(format!("{:.0}", r.achieved_ops_per_sec));
        eprintln!("done load={load}");
        rows.push(row);
    }
    print_table(
        &format!("fig7 {dist}: latency vs offered load"),
        &header,
        &rows,
    );
    println!("\n# E5 (tail latency, 6.2): compare *_p999 columns to *_mean --");
    println!("# paper: locks ~10x mean at low load, delegation ~2.5x mean.");
    }
}
