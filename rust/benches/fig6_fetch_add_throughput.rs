//! Figure 6a/6b: fetch-and-add throughput vs. object count.
//!
//! Series: Mutex / spinlock / MCS / flat-combining (TCLocks stand-in,
//! Fig 6a only in the paper) / Trust (blocking fibers) / Async
//! (non-blocking), each in shared and dedicated-trustee flavors.
//!
//! Usage: cargo bench --bench fig6_fetch_add_throughput -- \
//!            [--dist uniform|zipf] [--threads N] [--ops N] [--sizes 1,4,...]
//!            [--quick]

use trustee::bench::fadd::{run_async, run_lock_by_name, run_trust, FaddConfig};
use trustee::bench::print_table;
use trustee::util::cli::Args;

fn main() {
    let args = Args::from_env();
    let dist_arg = args.get_str("dist", "both");
    let quick = args.flag("quick");
    let threads: usize = args.get("threads", 4);
    let ops: u64 = args.get("ops", if quick { 2_000 } else { 10_000 });
    let default_sizes: &[u64] = if quick {
        &[1, 8, 64]
    } else {
        &[1, 2, 4, 8, 16, 32, 64, 128, 256, 1024]
    };
    let sizes = args.get_list::<u64>("sizes", default_sizes);
    let fibers: usize = args.get("fibers", 16);
    let dedicated: usize = args.get("dedicated", 1);

    let dists: Vec<&str> = if dist_arg == "both" { vec!["uniform", "zipf"] } else { vec![dist_arg.as_str()] };
    for dist in dists {
    let dist = dist.to_string();
    println!("# Figure 6{} reproduction: fetch-and-add throughput (MOPs) vs object count",
             if dist == "uniform" { "a (uniform)" } else { "b (zipfian a=1)" });
    println!("# threads={threads} ops/thread={ops} dist={dist} (paper: 128 threads, 1M ops)");

    let mut header = vec!["objects".to_string()];
    let engines = ["mutex", "spin", "mcs", "fc"];
    for e in engines {
        header.push(e.to_string());
    }
    header.extend([
        "trust-shared".to_string(),
        format!("trust-ded{dedicated}"),
        "async-shared".to_string(),
        format!("async-ded{dedicated}"),
    ]);

    let mut rows = Vec::new();
    for &objects in &sizes {
        let base = FaddConfig {
            threads,
            objects: objects as usize,
            ops_per_thread: ops,
            dist: dist.clone(),
            fibers,
            ..Default::default()
        };
        let mut row = vec![objects.to_string()];
        for name in engines {
            let r = run_lock_by_name(name, &base);
            row.push(format!("{:.3}", r.mops()));
        }
        let r = run_trust(&base);
        row.push(format!("{:.3}", r.mops()));
        let r = run_trust(&FaddConfig { dedicated, ..base.clone() });
        row.push(format!("{:.3}", r.mops()));
        let r = run_async(&base);
        row.push(format!("{:.3}", r.mops()));
        let r = run_async(&FaddConfig { dedicated, ..base.clone() });
        row.push(format!("{:.3}", r.mops()));
        eprintln!("done objects={objects}");
        rows.push(row);
    }
    let header_refs: Vec<&str> = header.iter().map(|s| s.as_str()).collect();
    print_table(
        &format!("fig6 {dist}: MOPs by engine and object count"),
        &header_refs,
        &rows,
    );
    }
}
