//! E16: RESP (Redis-protocol) front end throughput — trust vs mutex
//! backends under a fig-9-style write-percentage sweep, plus the response
//! buffer pool hit rate and the delegation-layer hot-path counters
//! (inline-completion spills, heap records, heap-pool hit rate) from the
//! allocation-free refactor (E17).
//!
//! Usage: cargo bench --bench resp_throughput -- \
//!            [--dist uniform|zipf] [--keys N] [--pcts 0,5,25,...]
//!            [--quick] [--json]
//!
//! With `--json`, one machine-readable object is printed to stdout —
//! `scripts/bench_smoke.sh` captures it as `BENCH_resp_throughput.json`
//! for cross-PR comparison.

use trustee::bench::print_table;
use trustee::kvstore::BackendKind;
use trustee::server::{run_resp_load, RespLoadConfig, RespServer, RespServerConfig};
use trustee::util::cli::Args;

fn main() {
    let args = Args::from_env();
    let quick = args.flag("quick");
    let json = args.flag("json");
    let dist = args.get_str("dist", "uniform");
    let keys: u64 = args.get("keys", 1_000);
    let default_pcts: &[u32] = if quick { &[5, 50] } else { &[0, 5, 25, 50, 75, 100] };
    let pcts = args.get_list::<u32>("pcts", default_pcts);
    let ops: u64 = args.get("ops", if quick { 2_000 } else { 5_000 });
    let client_threads: usize = args.get("client-threads", 2);

    if !json {
        println!(
            "# E16: RESP front end, kOPs vs write % ({keys} keys, {dist}); \
             cell = kOPs (response-buffer pool hit rate)"
        );
    }

    let configs = [
        ("TrustD2", BackendKind::Trust { shards: 8 }, 2usize),
        ("TrustS", BackendKind::Trust { shards: 8 }, 0),
        ("Mutex", BackendKind::Mutex, 0),
    ];
    let header = vec!["write_pct", "TrustD2", "TrustS", "Mutex"];
    let mut rows = Vec::new();
    let mut json_rows: Vec<String> = Vec::new();
    for &pct in &pcts {
        let mut row = vec![pct.to_string()];
        let mut cells: Vec<String> = Vec::new();
        for (label, backend, ded) in configs.clone() {
            let server = RespServer::start(RespServerConfig {
                workers: 4,
                dedicated: ded,
                backend,
                addr: "127.0.0.1:0".into(),
                ..Default::default()
            });
            server.prefill(keys, 16);
            let stats = run_resp_load(&RespLoadConfig {
                addr: server.addr(),
                threads: client_threads,
                pipeline: 32,
                ops_per_thread: ops,
                keys,
                dist: dist.clone(),
                write_pct: pct,
                ttl_pct: 0,
                val_len: 16,
                seed: 0xE16,
                retry_shed: false,
            });
            if !stats.ok() {
                eprintln!("client errors: {:?}", stats.errors);
            }
            // Connection fibers flush their pool counters on exit; give
            // them a beat after the load threads dropped their sockets.
            std::thread::sleep(std::time::Duration::from_millis(100));
            let t = server.metrics().totals();
            let hit_rate = t.pool_hits as f64 / ((t.pool_hits + t.pool_misses).max(1)) as f64;
            let hp = server.hot_path_stats();
            let kops = stats.throughput() / 1e3;
            row.push(format!("{kops:.1} ({:.0}%)", hit_rate * 100.0));
            cells.push(format!(
                "\"{label}\":{{\"kops\":{kops:.2},\"pool_hit_rate\":{hit_rate:.3},\
                 \"completion_heap_spills\":{},\"heap_records\":{},\
                 \"slot_bytes_copied\":{},\"resp_bytes\":{}}}",
                hp.completion_heap_spills, hp.heap_records, hp.slot_bytes_copied, t.resp_bytes
            ));
            server.stop();
        }
        eprintln!("done write_pct={pct}");
        json_rows.push(format!("{{\"write_pct\":{pct},{}}}", cells.join(",")));
        rows.push(row);
    }
    if json {
        println!(
            "{{\"bench\":\"resp_throughput\",\"dist\":\"{dist}\",\"keys\":{keys},\
             \"rows\":[{}]}}",
            json_rows.join(",")
        );
    } else {
        print_table(&format!("E16 {dist}: RESP kOPs vs write %"), &header, &rows);
    }
}
