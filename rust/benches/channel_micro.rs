//! E13 — channel microbenchmarks behind the paper's §6.1.2 capacity claim:
//! "even MCSLocks ... offer at best 2.5 MOPs. By comparison, a single
//! Trust<T> trustee will reliably offer 25 MOPs" — a ~10× single-object
//! capacity ratio.
//!
//! Measures: (1) single-pair round-trip latency (batch = 1),
//! (2) single-trustee throughput under windowed async load from all
//! clients, (3) single MCS lock and single Mutex throughput, and the
//! resulting trustee/MCS capacity ratio, plus (4) the batched-vs-eager
//! flush-policy scenario behind §5.3's amortization claim: the same
//! windowed fetch-add workload swept over worker count × async window
//! under both [`FlushPolicy::Eager`] (publish per request, the
//! pre-refactor behaviour) and [`FlushPolicy::Adaptive`] (outbox
//! watermark + phase-end flush). Adaptive should win ≥ 1.5x at 4+
//! workers, where per-request publishes leave most of each slot unused.
//!
//! Usage: cargo bench --bench channel_micro -- [--ops N] [--threads N]
//!
//! [`FlushPolicy::Eager`]: trustee::channel::FlushPolicy::Eager
//! [`FlushPolicy::Adaptive`]: trustee::channel::FlushPolicy::Adaptive

use trustee::bench::fadd::{run_async, run_lock_by_name, FaddConfig};
use trustee::bench::print_table;
use trustee::channel::FlushPolicy;
use trustee::runtime::Runtime;
use trustee::util::cli::Args;
use trustee::util::stats::fmt_ns;
use std::time::Instant;

fn round_trip_latency(ops: u64) -> f64 {
    let rt = Runtime::builder().workers(2).build();
    let ct = rt.block_on(0, || trustee::trust::local_trustee().entrust(0u64));
    let ct2 = ct.clone();
    let secs = rt.block_on(1, move || {
        // Warm up the edge.
        for _ in 0..100 {
            ct2.apply(|c| *c += 1);
        }
        let t0 = Instant::now();
        for _ in 0..ops {
            ct2.apply(|c| *c += 1);
        }
        t0.elapsed().as_secs_f64()
    });
    drop(ct);
    rt.shutdown();
    secs / ops as f64 * 1e9
}

fn main() {
    let args = Args::from_env();
    let ops: u64 = args.get("ops", 20_000);
    let threads: usize = args.get("threads", 4);

    let rtt = round_trip_latency(ops.min(20_000));

    // Single object = maximal contention: the §6.1.2 capacity comparison.
    let cfg = FaddConfig {
        threads,
        objects: 1,
        ops_per_thread: ops,
        window: 128,
        ..Default::default()
    };
    let mcs = run_lock_by_name("mcs", &cfg);
    let mutex = run_lock_by_name("mutex", &cfg);
    let trustee_async = run_async(&FaddConfig { dedicated: 1, ..cfg.clone() });

    print_table(
        "E13: single-object capacity (paper: MCS ~2.5 MOPs vs trustee ~25 MOPs, ~10x)",
        &["metric", "value"],
        &[
            vec!["apply() round-trip".into(), fmt_ns(rtt)],
            vec!["single MCS lock".into(), format!("{:.3} MOPs", mcs.mops())],
            vec!["single Mutex".into(), format!("{:.3} MOPs", mutex.mops())],
            vec![
                "single trustee (async, dedicated)".into(),
                format!("{:.3} MOPs", trustee_async.mops()),
            ],
            vec![
                "trustee/MCS capacity ratio".into(),
                format!("{:.2}x", trustee_async.mops() / mcs.mops()),
            ],
        ],
    );

    batched_vs_eager(ops);
}

/// The §5.3 amortization scenario: windowed async fetch-add against a
/// single trustee, swept over client-worker count × window (the natural
/// batch-size ceiling), eager vs adaptive flushing.
fn batched_vs_eager(ops: u64) {
    let mut rows = Vec::new();
    for workers in [2usize, 4, 6] {
        for window in [16usize, 64, 256] {
            let base = FaddConfig {
                threads: workers,
                objects: 1,
                ops_per_thread: ops,
                dedicated: 1,
                window,
                ..Default::default()
            };
            let eager = run_async(&FaddConfig { flush: FlushPolicy::Eager, ..base.clone() });
            let adaptive =
                run_async(&FaddConfig { flush: FlushPolicy::Adaptive, ..base.clone() });
            rows.push(vec![
                workers.to_string(),
                window.to_string(),
                format!("{:.3}", eager.mops()),
                format!("{:.3}", adaptive.mops()),
                format!("{:.2}x", adaptive.mops() / eager.mops()),
            ]);
            eprintln!("done workers={workers} window={window}");
        }
    }
    print_table(
        "E14: batched (adaptive flush) vs eager flush, async fetch-add, 1 dedicated trustee",
        &["client-workers", "window", "eager MOPs", "adaptive MOPs", "adaptive/eager"],
        &rows,
    );
}
