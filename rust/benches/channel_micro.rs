//! E13/E14/E17 — channel microbenchmarks behind the paper's §6.1.2
//! capacity claim: "even MCSLocks ... offer at best 2.5 MOPs. By
//! comparison, a single Trust<T> trustee will reliably offer 25 MOPs" — a
//! ~10× single-object capacity ratio.
//!
//! Measures: (1) single-pair round-trip latency (batch = 1),
//! (2) single-trustee throughput under windowed async load from all
//! clients, (3) single MCS lock and single Mutex throughput, and the
//! resulting trustee/MCS capacity ratio, (4) the batched-vs-eager
//! flush-policy scenario behind §5.3's amortization claim, and
//! (5) **steady-state allocations per delegated op** (E17): this binary
//! installs the counting allocator and differences two async runs of
//! different lengths, so fixed setup/teardown costs cancel and the
//! reported allocs/op isolates the hot path (expected: 0.00 after the
//! allocation-free refactor; the hard guarantee is
//! `tests/alloc_regression.rs`).
//!
//! Usage: cargo bench --bench channel_micro -- [--ops N] [--threads N]
//!        [--json]
//!
//! With `--json`, a single machine-readable object is printed to stdout
//! (progress goes to stderr) — `scripts/bench_smoke.sh` captures it as
//! `BENCH_channel_micro.json` so future changes have a perf baseline to
//! compare against.

use std::time::Instant;
use trustee::bench::fadd::{run_async, run_lock_by_name, FaddConfig};
use trustee::bench::print_table;
use trustee::channel::FlushPolicy;
use trustee::runtime::Runtime;
use trustee::util::cli::Args;
use trustee::util::count_alloc::{self, CountingAlloc};
use trustee::util::stats::fmt_ns;

#[global_allocator]
static ALLOC: CountingAlloc = CountingAlloc;

fn round_trip_latency(ops: u64) -> f64 {
    let rt = Runtime::builder().workers(2).build();
    let ct = rt.block_on(0, || trustee::trust::local_trustee().entrust(0u64));
    let ct2 = ct.clone();
    let secs = rt.block_on(1, move || {
        // Warm up the edge.
        for _ in 0..100 {
            ct2.apply(|c| *c += 1);
        }
        let t0 = Instant::now();
        for _ in 0..ops {
            ct2.apply(|c| *c += 1);
        }
        t0.elapsed().as_secs_f64()
    });
    drop(ct);
    rt.shutdown();
    secs / ops as f64 * 1e9
}

/// Steady-state allocations per async delegated op: difference two runs
/// whose op counts differ by `extra` — fixed runtime setup/teardown
/// allocations cancel, leaving only the per-op cost.
fn allocs_per_op(base: &FaddConfig) -> (f64, f64) {
    let short = FaddConfig { ops_per_thread: base.ops_per_thread, ..base.clone() };
    let long = FaddConfig { ops_per_thread: base.ops_per_thread * 2, ..base.clone() };
    let a0 = count_alloc::snapshot();
    run_async(&short);
    let a1 = count_alloc::snapshot();
    run_async(&long);
    let a2 = count_alloc::snapshot();
    let first = a1.since(&a0);
    let second = a2.since(&a1);
    let extra_ops = (base.ops_per_thread * base.threads as u64) as f64;
    let allocs = second.allocs.saturating_sub(first.allocs) as f64 / extra_ops;
    let bytes = second.bytes.saturating_sub(first.bytes) as f64 / extra_ops;
    (allocs, bytes)
}

fn main() {
    let args = Args::from_env();
    let ops: u64 = args.get("ops", 20_000);
    let threads: usize = args.get("threads", 4);
    let json = args.flag("json");

    let rtt = round_trip_latency(ops.min(20_000));

    // Single object = maximal contention: the §6.1.2 capacity comparison.
    let cfg = FaddConfig {
        threads,
        objects: 1,
        ops_per_thread: ops,
        window: 128,
        ..Default::default()
    };
    let mcs = run_lock_by_name("mcs", &cfg);
    let mutex = run_lock_by_name("mutex", &cfg);
    let trustee_async = run_async(&FaddConfig { dedicated: 1, ..cfg.clone() });
    eprintln!("done capacity comparison");

    let (aop, bop) = allocs_per_op(&FaddConfig { dedicated: 1, ..cfg.clone() });
    eprintln!("done allocs/op");

    let scenarios = batched_vs_eager(ops, json);

    if json {
        let rows: Vec<String> = scenarios
            .iter()
            .map(|s| {
                format!(
                    "{{\"workers\":{},\"window\":{},\"eager_mops\":{:.4},\"adaptive_mops\":{:.4}}}",
                    s.0, s.1, s.2, s.3
                )
            })
            .collect();
        println!(
            "{{\"bench\":\"channel_micro\",\"ops\":{ops},\"threads\":{threads},\
             \"rtt_ns\":{rtt:.1},\"mcs_mops\":{:.4},\"mutex_mops\":{:.4},\
             \"trustee_async_mops\":{:.4},\"trustee_mcs_ratio\":{:.3},\
             \"allocs_per_op\":{aop:.3},\"alloc_bytes_per_op\":{bop:.1},\
             \"batched_vs_eager\":[{}]}}",
            mcs.mops(),
            mutex.mops(),
            trustee_async.mops(),
            trustee_async.mops() / mcs.mops(),
            rows.join(",")
        );
        return;
    }

    print_table(
        "E13: single-object capacity (paper: MCS ~2.5 MOPs vs trustee ~25 MOPs, ~10x)",
        &["metric", "value"],
        &[
            vec!["apply() round-trip".into(), fmt_ns(rtt)],
            vec!["single MCS lock".into(), format!("{:.3} MOPs", mcs.mops())],
            vec!["single Mutex".into(), format!("{:.3} MOPs", mutex.mops())],
            vec![
                "single trustee (async, dedicated)".into(),
                format!("{:.3} MOPs", trustee_async.mops()),
            ],
            vec![
                "trustee/MCS capacity ratio".into(),
                format!("{:.2}x", trustee_async.mops() / mcs.mops()),
            ],
            vec![
                "steady-state allocs/op (async)".into(),
                format!("{aop:.3} ({bop:.1} B/op)"),
            ],
        ],
    );

    print_table(
        "E14: batched (adaptive flush) vs eager flush, async fetch-add, 1 dedicated trustee",
        &["client-workers", "window", "eager MOPs", "adaptive MOPs", "adaptive/eager"],
        &scenarios
            .iter()
            .map(|s| {
                vec![
                    s.0.to_string(),
                    s.1.to_string(),
                    format!("{:.3}", s.2),
                    format!("{:.3}", s.3),
                    format!("{:.2}x", s.3 / s.2),
                ]
            })
            .collect::<Vec<_>>(),
    );
}

/// The §5.3 amortization scenario: windowed async fetch-add against a
/// single trustee, swept over client-worker count × window (the natural
/// batch-size ceiling), eager vs adaptive flushing. Returns
/// (workers, window, eager MOPs, adaptive MOPs) rows.
fn batched_vs_eager(ops: u64, quiet: bool) -> Vec<(usize, usize, f64, f64)> {
    let mut rows = Vec::new();
    for workers in [2usize, 4, 6] {
        for window in [16usize, 64, 256] {
            let base = FaddConfig {
                threads: workers,
                objects: 1,
                ops_per_thread: ops,
                dedicated: 1,
                window,
                ..Default::default()
            };
            let eager = run_async(&FaddConfig { flush: FlushPolicy::Eager, ..base.clone() });
            let adaptive =
                run_async(&FaddConfig { flush: FlushPolicy::Adaptive, ..base.clone() });
            rows.push((workers, window, eager.mops(), adaptive.mops()));
            if !quiet {
                eprintln!("done workers={workers} window={window}");
            }
        }
    }
    rows
}
