//! E22: overload degradation curve — goodput and shed rate vs offered
//! concurrency, with admission control on (watermarked) and off
//! (`shed_high = 0`).
//!
//! The claim under test: past the shed watermark a watermarked server
//! degrades *gracefully* — goodput stays near capacity and the excess is
//! answered with cheap protocol-level overload errors — instead of
//! queueing without bound. Offered concurrency is swept by pipeline
//! depth (offered = client threads × pipeline); each level runs the same
//! storm against both tunings.
//!
//! Usage: cargo bench --bench overload_degradation -- \
//!            [--pipelines 1,4,16,...] [--shed-high Q] [--shed-low Q]
//!            [--keys N] [--ops N] [--quick] [--json]
//!
//! With `--json`, one machine-readable object is printed to stdout —
//! `scripts/bench_smoke.sh` captures it as
//! `BENCH_overload_degradation.json` for cross-PR comparison.

use trustee::bench::print_table;
use trustee::kvstore::BackendKind;
use trustee::memcache::{run_memtier, McdServer, McdServerConfig, MemtierConfig};
use trustee::server::ServerTuning;
use trustee::util::cli::Args;

struct Cell {
    goodput_kops: f64,
    shed_rate: f64,
    shed_metric: u64,
}

fn run_level(tuning: ServerTuning, pipeline: usize, threads: usize, keys: u64, ops: u64) -> Cell {
    let server = McdServer::start(McdServerConfig {
        workers: 2,
        backend: BackendKind::Trust { shards: 4 },
        tuning,
        ..Default::default()
    });
    server.prefill(keys, 16);
    let stats = run_memtier(&MemtierConfig {
        addr: server.addr(),
        threads,
        pipeline,
        ops_per_thread: ops,
        keys,
        dist: "uniform".into(),
        write_pct: 10,
        ttl_pct: 0,
        val_len: 16,
        seed: 0xE22,
        retry_shed: false,
    });
    if !stats.ok() {
        eprintln!("client errors: {:?}", stats.errors);
    }
    let served = stats.ops - stats.shed;
    let shed_metric = server.metrics().totals().shed;
    server.stop();
    Cell {
        goodput_kops: served as f64 / stats.elapsed.as_secs_f64() / 1e3,
        shed_rate: stats.shed as f64 / (stats.ops.max(1)) as f64,
        shed_metric,
    }
}

fn main() {
    let args = Args::from_env();
    let quick = args.flag("quick");
    let json = args.flag("json");
    let keys: u64 = args.get("keys", 512);
    let ops: u64 = args.get("ops", if quick { 1_500 } else { 5_000 });
    let threads: usize = args.get("client-threads", 2);
    let shed_high: u64 = args.get("shed-high", 64);
    let shed_low: u64 = args.get("shed-low", 48);
    let default_pipelines: &[usize] = if quick { &[4, 128] } else { &[1, 4, 16, 64, 256] };
    let pipelines = args.get_list::<usize>("pipelines", default_pipelines);

    let watermarked =
        ServerTuning { shed_high, shed_low, ..ServerTuning::default() };
    let unlimited = ServerTuning { shed_high: 0, ..ServerTuning::default() };

    if !json {
        println!(
            "# E22: overload degradation, memcached front end \
             ({keys} keys, shed band {shed_low}..{shed_high}); \
             cell = goodput kOPs (shed %)"
        );
    }

    let header = vec!["offered", "watermarked", "unlimited"];
    let mut rows = Vec::new();
    let mut json_rows: Vec<String> = Vec::new();
    for &pipeline in &pipelines {
        let offered = threads * pipeline;
        let shed = run_level(watermarked, pipeline, threads, keys, ops);
        let open = run_level(unlimited, pipeline, threads, keys, ops);
        rows.push(vec![
            offered.to_string(),
            format!("{:.1} ({:.0}%)", shed.goodput_kops, shed.shed_rate * 100.0),
            format!("{:.1} ({:.0}%)", open.goodput_kops, open.shed_rate * 100.0),
        ]);
        json_rows.push(format!(
            "{{\"pipeline\":{pipeline},\"offered\":{offered},\
             \"watermarked\":{{\"goodput_kops\":{:.2},\"shed_rate\":{:.4},\"shed\":{}}},\
             \"unlimited\":{{\"goodput_kops\":{:.2},\"shed_rate\":{:.4},\"shed\":{}}}}}",
            shed.goodput_kops, shed.shed_rate, shed.shed_metric,
            open.goodput_kops, open.shed_rate, open.shed_metric,
        ));
        eprintln!("done offered={offered}");
    }
    if json {
        println!(
            "{{\"bench\":\"overload_degradation\",\"shed_high\":{shed_high},\
             \"shed_low\":{shed_low},\"keys\":{keys},\"rows\":[{}]}}",
            json_rows.join(",")
        );
    } else {
        print_table("E22: goodput kOPs vs offered concurrency", &header, &rows);
    }
}
