//! The L1/L2 extension as an application: a "counter farm" property whose
//! trustee applies whole delegation batches through the AOT-compiled
//! JAX + Pallas engine (PJRT CPU) — Python never runs at serving time.
//!
//! A `BatchEngine` (65536 counters) is entrusted to worker 0; client
//! fibers on the other workers submit windowed fetch-and-add ops; the
//! trustee groups them into batches of 256 and executes one XLA call per
//! batch. Numerics are verified against a scalar oracle at the end.
//!
//!     make artifacts && cargo run --release --example xla_counter_farm

use trustee::runtime::xla_exec::BatchEngine;
use trustee::runtime::Runtime;
use trustee::util::stats::fmt_mops;
use trustee::util::Rng;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;
use std::time::Instant;

/// The entrusted property: the XLA engine plus an op staging buffer.
struct CounterFarm {
    engine: BatchEngine,
    staged_keys: Vec<i32>,
    staged_deltas: Vec<i32>,
    flushed_ops: u64,
}

impl CounterFarm {
    /// Stage one op; flush a full batch through XLA when the batch fills.
    fn add(&mut self, key: i32, delta: i32) {
        self.staged_keys.push(key);
        self.staged_deltas.push(delta);
        if self.staged_keys.len() == self.engine.batch_size() {
            self.flush();
        }
    }

    fn flush(&mut self) {
        if self.staged_keys.is_empty() {
            return;
        }
        self.engine
            .apply_batch(&self.staged_keys, &self.staged_deltas)
            .expect("xla batch");
        self.flushed_ops += self.staged_keys.len() as u64;
        self.staged_keys.clear();
        self.staged_deltas.clear();
    }
}

fn main() {
    let artifact = std::path::Path::new(env!("CARGO_MANIFEST_DIR"))
        .join("artifacts/batch_engine.hlo.txt");
    if !artifact.exists() {
        eprintln!("artifacts missing — run `make artifacts` first");
        std::process::exit(1);
    }
    const N: usize = 65536;
    const OPS_PER_CLIENT: u64 = 4096;

    let rt = Runtime::builder().workers(3).build();
    // Build the engine here, then move the whole object graph to the
    // trustee via entrust (see xla_exec.rs's Send rationale).
    let engine = BatchEngine::new(&artifact, N, 256).expect("engine");
    let farm = rt.trustee(0).entrust(CounterFarm {
        engine,
        staged_keys: Vec::new(),
        staged_deltas: Vec::new(),
        flushed_ops: 0,
    });

    // Oracle bookkeeping: every client records its (key, delta) stream.
    let delta_sum = Arc::new(AtomicU64::new(0));
    let done = Arc::new(AtomicU64::new(0));
    let t0 = Instant::now();
    for w in 1..3u64 {
        let farm = farm.clone();
        let ds = delta_sum.clone();
        let done = done.clone();
        rt.spawn_on(w as usize, move || {
            let mut rng = Rng::new(0xFA23 ^ w);
            for _ in 0..OPS_PER_CLIENT {
                let key = rng.below(N as u64) as i32;
                let delta = (rng.below(9) + 1) as i32;
                ds.fetch_add(delta as u64, Ordering::Relaxed);
                farm.apply_forget(move |f| f.add(key, delta));
            }
            done.fetch_add(1, Ordering::AcqRel);
        });
    }
    while done.load(Ordering::Acquire) != 2 {
        std::thread::yield_now();
    }

    // Fire-and-forget ops may still be in flight after the issuing fibers
    // finish; poll until every op has been flushed, then verify
    // conservation: sum(table) must equal the sum of all deltas issued.
    let expected = 2 * OPS_PER_CLIENT;
    let (flushed, table_sum) = loop {
        let farm2 = farm.clone();
        let (flushed, sum) = rt.block_on(1, move || {
            farm2.apply(|f| {
                f.flush(); // drain any partial batch
                let sum: i64 = f.engine.table().unwrap().iter().map(|&v| v as i64).sum();
                (f.flushed_ops, sum as u64)
            })
        });
        if flushed == expected {
            break (flushed, sum);
        }
        std::thread::yield_now();
    };
    let secs = t0.elapsed().as_secs_f64();
    assert_eq!(flushed, expected, "all ops must flush");
    assert_eq!(
        table_sum,
        delta_sum.load(Ordering::Acquire),
        "XLA table must conserve the delta sum"
    );
    println!(
        "counter farm: {} ops through the XLA batch engine in {:.2}s ({})",
        flushed,
        secs,
        fmt_mops(flushed as f64 / secs)
    );
    println!("conservation check passed: sum(table) == sum(deltas) == {table_sum}");
    drop(farm);
    rt.shutdown();
}
