//! Quickstart: the paper's Figures 1–3 as runnable code.
//!
//!     cargo run --release --example quickstart
//!
//! Walks through entrusting a property, synchronous `apply`, multi-threaded
//! sharing via `clone`, asynchronous `apply_then`, serialized arguments
//! with `apply_with`, and `launch` for blocking closures.

use trustee::runtime::Runtime;
use trustee::trust::{local_trustee, Latch};

fn main() {
    let rt = Runtime::builder().workers(4).build();

    // --- Figure 1: a minimal entrusted counter --------------------------
    rt.block_on(0, || {
        let ct = local_trustee().entrust(17u64); // Trust<u64>
        ct.apply(|c| *c += 1); // delegated increment
        assert_eq!(ct.apply(|c| *c), 18);
        println!("fig1: counter entrusted at 17, incremented -> 18");
    });

    // --- Figure 2a: sharing across threads ------------------------------
    let ct = rt.block_on(0, || local_trustee().entrust(17u64));
    let ct2 = ct.clone(); // refcount++ via delegation
    rt.block_on(1, move || {
        ct2.apply(|c| *c += 1); // from worker 1's fiber
    });
    ct.apply(|c| *c += 1); // from the main thread (injected slow path)
    assert_eq!(ct.apply(|c| *c), 19);
    println!("fig2: two contexts incremented a shared counter -> 19");

    // --- Figure 3: asynchronous delegation ------------------------------
    let ct3 = ct.clone();
    rt.block_on(1, move || {
        let got = std::rc::Rc::new(std::cell::Cell::new(0u64));
        let g = got.clone();
        ct3.apply_then(
            |c| {
                *c += 1;
                *c
            },
            move |v| g.set(v), // runs back on this worker
        );
        // In-order responses per client/trustee pair: a blocking apply
        // afterwards guarantees the callback has fired.
        let v = ct3.apply(|c| *c);
        assert_eq!(got.get(), 20);
        assert_eq!(v, 20);
        println!("fig3: apply_then callback observed {}", got.get());
    });

    // --- 4.3.3: variable-size arguments over the channel ----------------
    let table = rt.block_on(0, || {
        local_trustee().entrust(std::collections::HashMap::<String, String>::new())
    });
    let t2 = table.clone();
    rt.block_on(2, move || {
        t2.apply_with(
            |table, (key, value): (String, String)| {
                table.insert(key, value);
            },
            ("paper".to_string(), "Trust<T>".to_string()),
        );
        let v = t2.apply_with(|table, k: String| table.get(&k).cloned(), "paper".to_string());
        println!("apply_with: table[\"paper\"] = {v:?}");
        assert_eq!(v.as_deref(), Some("Trust<T>"));
    });

    // --- 4.3: launch() for blocking closures ----------------------------
    let inner = rt.block_on(0, || local_trustee().entrust(5u64));
    let latched = rt.block_on(0, || local_trustee().entrust(Latch::new(100u64)));
    let inner2 = inner.clone();
    let latched2 = latched.clone();
    let v = rt.block_on(3, move || {
        latched2.launch(move |x| {
            // Nested *blocking* delegation — would assert under apply().
            let add = inner2.apply(|i| *i);
            *x += add;
            *x
        })
    });
    assert_eq!(v, 105);
    println!("launch: blocking closure nested a delegation call -> {v}");

    drop((ct, table, inner, latched));
    rt.shutdown();
    println!("quickstart OK");
}
