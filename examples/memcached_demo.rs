//! Mini-memcached demo (§7): run the lock-based baseline and the
//! delegated Trust<T> backend of the **unified item store** side by side,
//! drive both with the memtier-style client, and print the speedup.
//!
//!     cargo run --release --example memcached_demo -- \
//!         [--keys 10000] [--ops 20000] [--dist zipf] [--write-pct 5] \
//!         [--ttl-pct 0] [--budget-mb 0]
//!
//! `--ttl-pct` makes that share of the sets carry `exptime 1`, exercising
//! the store's expiry machinery end to end (expired keys then miss);
//! `--budget-mb` caps the store and triggers per-shard LRU eviction.

use trustee::kvstore::BackendKind;
use trustee::memcache::{run_memtier, McdServer, McdServerConfig, MemtierConfig};
use trustee::util::cli::Args;
use trustee::util::stats::fmt_mops;

fn main() {
    let args = Args::from_env();
    let keys: u64 = args.get("keys", 10_000);
    let ops: u64 = args.get("ops", 20_000);
    let dist = args.get_str("dist", "zipf");
    let write_pct: u32 = args.get("write-pct", 5);
    let ttl_pct: u32 = args.get("ttl-pct", 0);
    let budget_bytes: u64 = args.get::<u64>("budget-mb", 0) << 20;

    println!("== mini-memcached: lock baseline vs Trust<T> (unified item store) ==");
    println!(
        "keys={keys} ops={ops} dist={dist} writes={write_pct}% ttl={ttl_pct}% \
         budget={budget_bytes}B pipeline=48"
    );

    let mut tputs = Vec::new();
    for backend in [BackendKind::Mutex, BackendKind::Trust { shards: 8 }] {
        let label = backend.label();
        let server = McdServer::start(McdServerConfig {
            workers: 4,
            dedicated: 0,
            backend,
            budget_bytes,
            addr: "127.0.0.1:0".into(),
            ..Default::default()
        });
        server.prefill(keys, 16);
        let stats = run_memtier(&MemtierConfig {
            addr: server.addr(),
            threads: 2,
            pipeline: 48,
            ops_per_thread: ops / 2,
            keys,
            dist: dist.clone(),
            write_pct,
            ttl_pct,
            val_len: 16,
            seed: 0xDEC0,
        });
        if ttl_pct == 0 && budget_bytes == 0 {
            assert_eq!(stats.misses, 0, "prefilled keys must not miss");
        }
        let store = server.store_stats();
        println!(
            "{label:<12} {:>14}  ({} ops in {:.2}s | misses {} | evictions {} expired {})",
            fmt_mops(stats.throughput()),
            stats.ops,
            stats.elapsed.as_secs_f64(),
            stats.misses,
            store.evictions,
            store.expired_keys,
        );
        tputs.push(stats.throughput());
        server.stop();
    }
    println!(
        "\ndelegated/lock speedup: {:.2}x (paper fig 10/11: up to 5-9x under contention)",
        tputs[1] / tputs[0]
    );
}
