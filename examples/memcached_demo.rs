//! Mini-memcached demo (§7): start the stock and delegated engines side by
//! side, drive both with the memtier-style client, and print the speedup.
//!
//!     cargo run --release --example memcached_demo -- \
//!         [--keys 10000] [--ops 20000] [--dist zipf] [--write-pct 5]

use trustee::memcache::{run_memtier, EngineKind, McdServer, McdServerConfig, MemtierConfig};
use trustee::util::cli::Args;
use trustee::util::stats::fmt_mops;

fn main() {
    let args = Args::from_env();
    let keys: u64 = args.get("keys", 10_000);
    let ops: u64 = args.get("ops", 20_000);
    let dist = args.get_str("dist", "zipf");
    let write_pct: u32 = args.get("write-pct", 5);

    println!("== mini-memcached: stock (locks) vs Trust<T> (delegated shards) ==");
    println!("keys={keys} ops={ops} dist={dist} writes={write_pct}% pipeline=48");

    let mut tputs = Vec::new();
    for engine in [EngineKind::Stock, EngineKind::Trust { shards: 8 }] {
        let label = engine.label();
        let server = McdServer::start(McdServerConfig {
            workers: 4,
            dedicated: 0,
            engine,
            addr: "127.0.0.1:0".into(),
            ..Default::default()
        });
        server.prefill(keys, 16);
        let stats = run_memtier(&MemtierConfig {
            addr: server.addr(),
            threads: 2,
            pipeline: 48,
            ops_per_thread: ops / 2,
            keys,
            dist: dist.clone(),
            write_pct,
            val_len: 16,
            seed: 0xDEC0,
        });
        assert_eq!(stats.misses, 0, "prefilled keys must not miss");
        println!("{label:<12} {:>14}  ({} ops in {:.2}s)",
                 fmt_mops(stats.throughput()), stats.ops,
                 stats.elapsed.as_secs_f64());
        tputs.push(stats.throughput());
        server.stop();
    }
    println!("\ndelegated/stock speedup: {:.2}x (paper fig 10/11: up to 5-9x under contention)",
             tputs[1] / tputs[0]);
}
