//! End-to-end driver (the DESIGN.md E2E validation run): bring up the full
//! system — Trust\<T\> runtime, delegated shards, TCP server, socket-worker
//! fibers — put a real workload through it over loopback, and report the
//! paper's headline metric (delegation vs locking throughput under
//! contention) plus latency percentiles.
//!
//!     cargo run --release --example kv_store_e2e -- \
//!         [--keys 1000] [--ops 20000] [--dist zipf] [--write-pct 5]
//!
//! Results from this driver are recorded in EXPERIMENTS.md §E2E.

use trustee::kvstore::{run_load, BackendKind, KvServer, KvServerConfig, LoadConfig};
use trustee::util::cli::Args;
use trustee::util::stats::{fmt_mops, fmt_ns};

fn main() {
    let args = Args::from_env();
    let keys: u64 = args.get("keys", 1_000);
    let ops: u64 = args.get("ops", 20_000);
    let dist = args.get_str("dist", "zipf");
    let write_pct: u32 = args.get("write-pct", 5);
    let threads: usize = args.get("client-threads", 2);

    println!("== Trust<T> KV store end-to-end ==");
    println!("keys={keys} ops={ops} dist={dist} writes={write_pct}% clients={threads}");

    let mut results = Vec::new();
    for (label, backend, dedicated) in [
        ("Trust (delegated, 2 dedicated)", BackendKind::Trust { shards: 8 }, 2usize),
        ("Trust (delegated, shared)", BackendKind::Trust { shards: 8 }, 0),
        ("Sharded Mutex", BackendKind::Mutex, 0),
        ("Sharded RwLock", BackendKind::RwLock, 0),
        ("Dashmap-like", BackendKind::Swift, 0),
    ] {
        let server = KvServer::start(KvServerConfig {
            workers: 4,
            dedicated,
            backend,
            addr: "127.0.0.1:0".into(),
            ..Default::default()
        });
        server.prefill(keys, 16);
        let stats = run_load(&LoadConfig {
            addr: server.addr(),
            threads,
            pipeline: 32,
            ops_per_thread: ops / threads as u64,
            keys,
            dist: dist.clone(),
            write_pct,
            val_len: 16,
            seed: 0xE2E,
        });
        assert_eq!(stats.misses, 0, "prefilled keys must not miss");
        println!(
            "{label:<32} {:>14}   mean {:>10}   p99.9 {:>10}",
            fmt_mops(stats.throughput()),
            fmt_ns(stats.hist.mean()),
            fmt_ns(stats.hist.quantile(0.999) as f64),
        );
        results.push((label, stats.throughput()));
        server.stop();
    }

    let trust = results[0].1.max(results[1].1);
    let best_lock = results[2..].iter().map(|r| r.1).fold(0.0f64, f64::max);
    println!(
        "\nheadline: delegation/locking throughput ratio = {:.2}x ({dist} dist, {keys} keys)",
        trust / best_lock
    );
    println!("paper (fig 8/9, congested): 5-9x; uncongested: ~1x. See EXPERIMENTS.md.");
}
