"""Kernel-vs-oracle correctness: the core L1 signal.

hypothesis sweeps batch/table shapes and op contents; every case asserts the
Pallas kernel (interpret=True) matches the pure-jnp scan oracle exactly
(integer workload: allclose == equal)."""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels.batch_apply import batch_apply, shard_route
from compile.kernels.ref import batch_apply_ref, shard_route_ref


def run_both(table, idx, delta):
    t1, o1 = batch_apply(jnp.array(table, jnp.int32),
                         jnp.array(idx, jnp.int32),
                         jnp.array(delta, jnp.int32))
    t2, o2 = batch_apply_ref(jnp.array(table, jnp.int32),
                             jnp.array(idx, jnp.int32),
                             jnp.array(delta, jnp.int32))
    np.testing.assert_array_equal(np.asarray(t1), np.asarray(t2))
    np.testing.assert_array_equal(np.asarray(o1), np.asarray(o2))
    return np.asarray(t1), np.asarray(o1)


def test_single_op():
    table, old = run_both([10, 20, 30], [1], [5])
    assert list(table) == [10, 25, 30]
    assert list(old) == [20]


def test_duplicate_indices_accumulate_in_order():
    # Two increments of the same hot key: the second must see the first.
    table, old = run_both([100], [0, 0, 0], [1, 2, 3])
    assert list(table) == [106]
    assert list(old) == [100, 101, 103]


def test_zero_delta_is_pure_read():
    table, old = run_both([7, 8], [0, 1, 0], [0, 0, 0])
    assert list(table) == [7, 8]
    assert list(old) == [7, 8, 7]


def test_negative_deltas():
    table, old = run_both([50], [0, 0], [-20, -30])
    assert list(table) == [0]
    assert list(old) == [50, 30]


@settings(max_examples=40, deadline=None)
@given(
    n=st.integers(min_value=1, max_value=512),
    b=st.integers(min_value=1, max_value=128),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
)
def test_matches_oracle_random_shapes(n, b, seed):
    rng = np.random.default_rng(seed)
    table = rng.integers(-1000, 1000, size=n, dtype=np.int32)
    idx = rng.integers(0, n, size=b, dtype=np.int32)
    delta = rng.integers(-100, 100, size=b, dtype=np.int32)
    run_both(table, idx, delta)


@settings(max_examples=20, deadline=None)
@given(
    b=st.integers(min_value=1, max_value=256),
    shards=st.sampled_from([1, 2, 16, 64]),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
)
def test_shard_route_matches_oracle(b, shards, seed):
    rng = np.random.default_rng(seed)
    keys = rng.integers(0, 2**31 - 1, size=b, dtype=np.int32)
    got = np.asarray(shard_route(jnp.array(keys), shards))
    want = np.asarray(shard_route_ref(jnp.array(keys), shards))
    np.testing.assert_array_equal(got, want)
    assert got.min() >= 0 and got.max() < shards


def test_shard_route_spreads():
    keys = jnp.arange(4096, dtype=jnp.int32)
    shards = np.asarray(shard_route(keys, 64))
    counts = np.bincount(shards, minlength=64)
    # Roughly balanced: no shard more than 3x the mean.
    assert counts.max() < 3 * counts.mean()


def test_conservation_property():
    # Sum(table) after == sum(table) before + sum(delta): no lost updates.
    rng = np.random.default_rng(7)
    table = rng.integers(0, 100, size=64, dtype=np.int32)
    idx = rng.integers(0, 64, size=200, dtype=np.int32)
    delta = rng.integers(-5, 6, size=200, dtype=np.int32)
    new_table, _ = run_both(table, idx, delta)
    assert new_table.sum() == table.sum() + delta.sum()
