"""L2 model tests: the composed engine step and the AOT lowering path."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile.aot import to_hlo_text
from compile.model import AOT_VARIANTS, engine_step, engine_step_ref, lowered


def test_engine_step_matches_ref():
    rng = np.random.default_rng(3)
    table = jnp.array(rng.integers(0, 50, size=256, dtype=np.int32))
    keys = jnp.array(rng.integers(0, 2**31 - 1, size=64, dtype=np.int32))
    delta = jnp.array(rng.integers(0, 3, size=64, dtype=np.int32))
    t1, o1, s1 = engine_step(table, keys, delta)
    t2, o2, s2 = engine_step_ref(table, keys, delta)
    np.testing.assert_array_equal(np.asarray(t1), np.asarray(t2))
    np.testing.assert_array_equal(np.asarray(o1), np.asarray(o2))
    np.testing.assert_array_equal(np.asarray(s1), np.asarray(s2))


def test_engine_step_output_shapes():
    table = jnp.zeros(1024, jnp.int32)
    keys = jnp.zeros(32, jnp.int32)
    delta = jnp.ones(32, jnp.int32)
    t, o, s = engine_step(table, keys, delta)
    assert t.shape == (1024,)
    assert o.shape == (32,)
    assert s.shape == (32,)
    assert t.dtype == jnp.int32


@pytest.mark.parametrize("name,shape", sorted(AOT_VARIANTS.items()))
def test_lowering_produces_hlo_text(name, shape):
    text = to_hlo_text(lowered(**shape))
    # Sanity: it is HLO text with an entry computation and our shapes.
    assert "ENTRY" in text
    assert f"s32[{shape['n']}]" in text
    assert f"s32[{shape['b']}]" in text
    # The interchange constraint: text, not serialized proto (str is enough).
    assert isinstance(text, str) and len(text) > 100


def test_single_fused_module_no_host_callbacks():
    # interpret=True must lower to plain HLO ops (no custom-call): that is
    # what lets the rust CPU PJRT client run it.
    text = to_hlo_text(lowered(n=256, b=16))
    assert "custom-call" not in text.lower()
