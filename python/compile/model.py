"""L2: the trustee batch-engine compute graph.

Composes the L1 kernels into the function the Rust runtime executes per
delegation batch: route each op's key to a shard-local index, apply the
batch of fetch-and-adds in submission order, and gather the responses.
For read ops (`delta == 0`) the fetch-and-add *is* the read, so one graph
serves the paper's mixed GET/PUT-style batches.

The whole step is one jit so XLA fuses routing, the Pallas batch-apply,
and the response gather into a single executable — this is the module AOT
lowering hands to the Rust PJRT runtime.
"""

import jax
import jax.numpy as jnp

from .kernels.batch_apply import batch_apply, shard_route


def engine_step(table, keys, delta):
    """One trustee batch: (table, keys, delta) -> (new_table, old, shard).

    Args:
      table: (N,) int32 counter table for this trustee's shard group.
      keys:  (B,) int32 raw op keys (pre-hash).
      delta: (B,) int32 increments (0 = pure fetch/read).

    Returns a tuple:
      new_table: (N,) int32
      old:       (B,) int32 — pre-increment values (the responses)
      shard:     (B,) int32 — routing decision per op (for L3 telemetry)
    """
    n = table.shape[0]
    shard = shard_route(keys, 64)
    # Map keys into table indices (the shard's local slot space).
    idx = (keys.astype(jnp.uint32) % jnp.uint32(n)).astype(jnp.int32)
    new_table, old = batch_apply(table, idx, delta)
    return new_table, old, shard


def engine_step_ref(table, keys, delta):
    """Oracle composition used by the pytest suite."""
    from .kernels.ref import batch_apply_ref, shard_route_ref

    n = table.shape[0]
    shard = shard_route_ref(keys, 64)
    idx = (keys.astype(jnp.uint32) % jnp.uint32(n)).astype(jnp.int32)
    new_table, old = batch_apply_ref(table, idx, delta)
    return new_table, old, shard


#: Shapes the AOT pipeline compiles (one executable per variant, as the
#: runtime design prescribes: "one compiled executable per model variant").
AOT_VARIANTS = {
    "batch_engine": dict(n=65536, b=256),
    "batch_engine_small": dict(n=1024, b=32),
}


def lowered(n, b):
    """jax.jit(...).lower(...) for a (table=n, batch=b) variant."""
    spec_t = jax.ShapeDtypeStruct((n,), jnp.int32)
    spec_b = jax.ShapeDtypeStruct((b,), jnp.int32)
    return jax.jit(engine_step).lower(spec_t, spec_b, spec_b)
