"""AOT lowering: JAX -> HLO *text* -> artifacts/*.hlo.txt.

HLO text (NOT ``lowered.compile().serialize()`` or proto bytes) is the
interchange format: jax >= 0.5 emits HloModuleProto with 64-bit instruction
ids which the ``xla`` crate's xla_extension 0.5.1 rejects
(``proto.id() <= INT_MAX``); the text parser reassigns ids and round-trips
cleanly (see /opt/xla-example/README.md and gen_hlo.py).

Run once via ``make artifacts``; the Rust binary is self-contained after.
"""

import argparse
import pathlib

from jax._src.lib import xla_client as xc

from .model import AOT_VARIANTS, lowered


def to_hlo_text(low) -> str:
    mlir_mod = low.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts/model.hlo.txt",
                    help="path for the primary artifact; variants are "
                         "written as siblings named <variant>.hlo.txt")
    args = ap.parse_args()
    primary = pathlib.Path(args.out)
    outdir = primary.parent
    outdir.mkdir(parents=True, exist_ok=True)

    for name, shape in AOT_VARIANTS.items():
        text = to_hlo_text(lowered(**shape))
        path = outdir / f"{name}.hlo.txt"
        path.write_text(text)
        print(f"wrote {name}: {len(text)} chars -> {path} (n={shape['n']}, b={shape['b']})")

    # The Makefile's stamp target: primary artifact aliases batch_engine.
    primary.write_text((outdir / "batch_engine.hlo.txt").read_text())
    print(f"wrote primary artifact {primary}")


if __name__ == "__main__":
    main()
