"""Pure-jnp correctness oracles for the L1 kernels.

These are the executable spec: `batch_apply_ref` expresses the trustee's
sequential closure application directly with `lax.scan` (carrying the table
through each op), with none of the Pallas machinery.
"""

import jax
import jax.numpy as jnp


def batch_apply_ref(table, idx, delta):
    """Sequential-semantics batched fetch-and-add, as a scan."""

    def step(tbl, op):
        j, d = op
        old = tbl[j]
        return tbl.at[j].set(old + d), old

    new_table, old = jax.lax.scan(step, table, (idx, delta))
    return new_table, old


def shard_route_ref(keys, n_shards):
    """Same FNV-1a-style mix as the kernel, in plain jnp."""
    k = keys.astype(jnp.uint32)
    h = (k ^ jnp.uint32(2166136261)) * jnp.uint32(16777619)
    h = (h ^ (h >> 13)) * jnp.uint32(0x5BD1E995)
    h = h ^ (h >> 15)
    return (h % jnp.uint32(n_shards)).astype(jnp.int32)
