"""L1 Pallas kernel: trustee-side batched apply of delegated operations.

The paper's trustee applies the N closures of a request batch *sequentially*
(§5.2); for homogeneous operations (the fetch-and-add microbenchmark of
§6.1, counter/accumulator properties) the whole batch can instead be applied
as one kernel launch. This kernel is the Trust<T> batch engine's hot spot:

    for i in 0..B:                        # in submission order
        old[i]        = table[idx[i]]
        table[idx[i]] = old[i] + delta[i]

In-order semantics matter: duplicate indices must observe one another
(two increments of a hot key in one batch accumulate, and each sees the
running value), exactly as the trustee's sequential closure execution would.
A vectorized scatter-add would break the *fetch* half for duplicates, so the
kernel is a `fori_loop` over the batch with the table resident in VMEM.

Hardware adaptation (DESIGN.md §Hardware-Adaptation): the table block plus
the three B-vectors are the VMEM working set; a real-TPU deployment tiles
`table` via BlockSpec so a shard's counters stay resident across batches —
the analogue of the paper keeping the property hot in the trustee's cache.
Lowered with interpret=True: CPU PJRT cannot execute Mosaic custom-calls.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _batch_apply_kernel(table_ref, idx_ref, delta_ref, table_out_ref, old_out_ref):
    """Apply B fetch-and-add ops to the table, in order."""
    # Copy the table block into the output ref once; then mutate in place.
    table_out_ref[...] = table_ref[...]

    def body(i, _):
        j = idx_ref[i]
        old = table_out_ref[j]
        old_out_ref[i] = old
        table_out_ref[j] = old + delta_ref[i]
        return _

    jax.lax.fori_loop(0, idx_ref.shape[0], body, 0)


@functools.partial(jax.jit, static_argnames=())
def batch_apply(table, idx, delta):
    """Pallas-backed batched fetch-and-add.

    Args:
      table: (N,) int32 — the entrusted counter table (one shard).
      idx:   (B,) int32 — target index per op, in submission order.
      delta: (B,) int32 — increment per op.

    Returns:
      (new_table, old): the updated table and the pre-increment values —
      the batch of delegation *responses*.
    """
    n = table.shape[0]
    b = idx.shape[0]
    return pl.pallas_call(
        _batch_apply_kernel,
        out_shape=(
            jax.ShapeDtypeStruct((n,), jnp.int32),
            jax.ShapeDtypeStruct((b,), jnp.int32),
        ),
        interpret=True,  # CPU PJRT: Mosaic custom-calls are TPU-only
    )(table, idx, delta)


def _shard_route_kernel(keys_ref, out_ref, *, n_shards):
    """FNV-1a-style mix of each key -> shard id (vectorized, no loop)."""
    k = keys_ref[...].astype(jnp.uint32)
    h = (k ^ jnp.uint32(2166136261)) * jnp.uint32(16777619)
    h = (h ^ (h >> 13)) * jnp.uint32(0x5BD1E995)
    h = h ^ (h >> 15)
    out_ref[...] = (h % jnp.uint32(n_shards)).astype(jnp.int32)


def shard_route(keys, n_shards):
    """Route a batch of keys to shards (the L3 router's hash, vectorized)."""
    b = keys.shape[0]
    return pl.pallas_call(
        functools.partial(_shard_route_kernel, n_shards=n_shards),
        out_shape=jax.ShapeDtypeStruct((b,), jnp.int32),
        interpret=True,
    )(keys)
